"""Deterministic fault injection (serving/faults.py), tier-1: seeded
plan determinism, JSON round-trips, the ``REPRO_FAULTS`` environment
hook, and injector semantics against REAL framed connections — drops,
windows and half-opens are frames that never reach the wire, asserted
by reading the wire. Unlabeled connections (what worker child processes
hold) must never be faulted, and uninstall must restore a clean
transport."""
import threading
import time

import pytest

from repro.serving import faults as FLT
from repro.serving import transport as TR


@pytest.fixture(autouse=True)
def _clean_hook():
    """No test may leak an installed injector into the rest of the
    suite (the hook is process-global)."""
    yield
    FLT.uninstall()


# ---------------------------------------------------------------- plans
def test_seeded_plan_is_deterministic_and_has_the_chaos_mix():
    peers = ["w1", "w2", "w3"]
    p1 = FLT.FaultPlan.seeded(7, peers)
    p2 = FLT.FaultPlan.seeded(7, peers)
    assert p1.to_json() == p2.to_json()
    assert FLT.FaultPlan.seeded(8, peers).to_json() != p1.to_json()
    kinds = [e.kind for e in p1.events]
    assert kinds.count("kill") == 1
    assert kinds.count("half_open") == 1
    assert kinds.count("partition") == 1
    assert kinds.count("delay") == 4 * len(peers)
    # roles are distinct peers when there are >= 3
    roles = {e.peer for e in p1.events if e.kind != "delay"}
    assert len(roles) == 3


def test_plan_json_roundtrip_via_file(tmp_path):
    plan = FLT.FaultPlan.seeded(3, ["w1", "w2"])
    path = str(tmp_path / "plan.json")
    plan.save(path)
    back = FLT.FaultPlan.load(path)
    assert back.seed == 3
    assert back.to_json() == plan.to_json()


def test_unknown_fault_kind_rejected():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FLT.FaultEvent(peer="w1", kind="explode")


def test_env_hook_installs_a_serialized_plan(tmp_path, monkeypatch):
    plan = FLT.FaultPlan.seeded(1, ["w1"])
    path = str(tmp_path / "plan.json")
    plan.save(path)
    monkeypatch.setenv("REPRO_FAULTS", path)
    TR._install_env_faults()     # what transport import runs
    inj = FLT.active()
    assert inj is not None
    assert inj.plan.to_json() == plan.to_json()


# ------------------------------------------------------------- injector
def test_drop_and_partition_swallow_exactly_the_scheduled_frames():
    a, b = TR.socketpair()
    a.peer_label = "w1"
    inj = FLT.FaultInjector()
    inj.arm("w1", "drop", at_op=1)
    inj.arm("w1", "partition", at_op=3, span=2)
    FLT.install(inj)
    for i in range(6):
        a.send({"op": i})
    # op 1 dropped, ops 3-4 partitioned: only 0, 2, 5 reach the wire
    assert [b.recv()["op"] for _ in range(3)] == [0, 2, 5]
    assert inj.injected["drop"] == 1
    assert inj.injected["partition"] == 2
    assert inj.ops_sent("w1") == 6
    assert FLT.injected_total() == 3


def test_half_open_blackholes_everything_from_at_op():
    a, b = TR.socketpair()
    a.peer_label = "w2"
    inj = FLT.install(FLT.FaultPlan(events=[
        FLT.FaultEvent(peer="w2", kind="half_open", at_op=2)]))
    for i in range(5):
        a.send({"op": i})
    assert [b.recv()["op"] for _ in range(2)] == [0, 1]
    assert inj.injected["half_open"] == 3
    # the socket is OPEN the whole time: this is deadline territory,
    # never TransportClosed
    assert a.tx_frames == 2


def test_delay_holds_the_frame_but_delivers_it():
    a, b = TR.socketpair()
    a.peer_label = "w1"
    inj = FLT.install(FLT.FaultPlan(events=[
        FLT.FaultEvent(peer="w1", kind="delay", at_op=0, delay_s=0.05)]))
    got = {}

    def reader():
        got["msg"] = b.recv()

    t = threading.Thread(target=reader, daemon=True)
    t.start()
    t0 = time.perf_counter()
    a.send({"x": 1})
    assert time.perf_counter() - t0 >= 0.04
    t.join(timeout=5)
    assert got["msg"] == {"x": 1}
    assert inj.injected["delay"] == 1


def test_unlabeled_connections_are_never_faulted():
    a, b = TR.socketpair()          # peer_label stays None
    inj = FLT.install(FLT.FaultPlan(events=[
        FLT.FaultEvent(peer="w1", kind="half_open", at_op=0)]))
    a.send({"x": 1})
    assert b.recv() == {"x": 1}
    assert inj.total_injected() == 0


def test_uninstall_restores_a_clean_transport():
    a, b = TR.socketpair()
    a.peer_label = "w1"
    FLT.install(FLT.FaultPlan(events=[
        FLT.FaultEvent(peer="w1", kind="half_open", at_op=0)]))
    a.send({"x": 1})                # swallowed
    FLT.uninstall()
    a.send({"x": 2})                # delivered: hook is gone
    assert b.recv() == {"x": 2}
    assert FLT.active() is None
    assert FLT.injected_total() == 0


def test_kills_are_step_keyed_and_consumed_once():
    inj = FLT.FaultInjector(FLT.FaultPlan(events=[
        FLT.FaultEvent(peer="w1", kind="kill", at_step=3),
        FLT.FaultEvent(peer="w2", kind="kill", at_step=3)]))
    assert inj.kills_due(2) == []
    assert sorted(inj.kills_due(3)) == ["w1", "w2"]
    assert inj.kills_due(3) == []   # consumed
    assert inj.injected["kill"] == 2


def test_arm_without_at_op_targets_the_very_next_send():
    a, b = TR.socketpair()
    a.peer_label = "w1"
    inj = FLT.install(FLT.FaultPlan())
    a.send({"op": 0})
    inj.arm("w1", "drop")           # next send (op 1) is the target
    a.send({"op": 1})
    a.send({"op": 2})
    assert [b.recv()["op"] for _ in range(2)] == [0, 2]
