"""Bench sanity gate (benchmarks/check_bench.py), tier-1: the nightly
job's tripwire must itself be trustworthy — it catches unparseable
reports, missing/false smoke flags, and dropped required keys, and
passes the reports the harness actually emits."""
import json
import os
import sys

# benchmarks/ is a root-level namespace package, not on src/
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.check_bench import REQUIRED_KEYS, check_report, main  # noqa: E402


def _write(tmp_path, name, payload):
    p = tmp_path / name
    p.write_text(payload if isinstance(payload, str) else json.dumps(payload))
    return str(p)


def test_clean_report_passes(tmp_path):
    path = _write(tmp_path, "BENCH_paged_engine.json",
                  {"smoke": True, "config": {}, "dense": {}, "paged": {},
                   "paged_over_dense_speedup": 9.7, "mixed_trace": {}})
    assert check_report(path, smoke_run=True) == []


def test_unparseable_and_wrong_shape_reports(tmp_path):
    bad = _write(tmp_path, "BENCH_distributed.json", "{truncated")
    (problem,) = check_report(bad, smoke_run=False)
    assert "does not parse" in problem
    arr = _write(tmp_path, "BENCH_module_scaling.json", [1, 2])
    (problem,) = check_report(arr, smoke_run=False)
    assert "not an object" in problem


def test_smoke_flag_is_enforced_on_smoke_runs(tmp_path):
    path = _write(tmp_path, "BENCH_prefix_sharing.json",
                  {"smoke": False, "config": {}, "sharing_on": {},
                   "sharing_off": {}, "peak_block_ratio": 0.55,
                   "token_identical": True})
    assert check_report(path, smoke_run=False) == []
    problems = check_report(path, smoke_run=True)
    assert any("smoke=false" in p for p in problems)
    missing = _write(tmp_path, "BENCH_other.json", {"anything": 1})
    assert any("'smoke'" in p for p in check_report(missing, False))


def test_required_keys_are_checked(tmp_path):
    path = _write(tmp_path, "BENCH_distributed.json",
                  {"smoke": True, "config": {}, "migration_stall": {},
                   "burst": {}, "dropped_requests": 0, "recoveries": 0})
    problems = check_report(path, smoke_run=True)
    assert problems == [
        "BENCH_distributed.json: missing required key 'control_plane'"]


def test_main_exit_codes(tmp_path, monkeypatch, capsys):
    monkeypatch.setenv("REPRO_BENCH_SMOKE", "1")
    good = _write(tmp_path, "BENCH_paged_engine.json",
                  {"smoke": True, "config": {}, "dense": {}, "paged": {},
                   "paged_over_dense_speedup": 1.0, "mixed_trace": {}})
    assert main([good]) == 0
    # distinct filename: the clean report must stay clean alongside the
    # bad one (a shared name would silently overwrite it)
    bad = _write(tmp_path, "BENCH_module_scaling.json",
                 {"smoke": False, "config": {}})
    assert main([good, bad]) == 1
    captured = capsys.readouterr()
    assert "smoke=false" in captured.err
    assert "missing required key" in captured.err
    assert "1 clean" in captured.out


def test_registry_covers_every_emitting_bench():
    # every bench module that json.dumps a report should be registered
    # here — this list is the reminder to extend REQUIRED_KEYS when a
    # new bench starts emitting
    assert set(REQUIRED_KEYS) == {
        "BENCH_chaos.json", "BENCH_distributed.json",
        "BENCH_ingress.json", "BENCH_module_scaling.json",
        "BENCH_observe.json", "BENCH_paged_engine.json",
        "BENCH_prefix_sharing.json", "BENCH_slo.json"}


def test_ingress_report_keys_match_the_emitter(tmp_path):
    # the keys the acceptance criteria read (routing gate, elasticity
    # capacity gain, token identity, drop count) are required
    assert set(REQUIRED_KEYS["BENCH_ingress.json"]) == {
        "config", "streaming", "routing", "elasticity",
        "token_identical", "dropped_requests"}
    path = _write(tmp_path, "BENCH_ingress.json",
                  {"smoke": True, "config": {}, "streaming": {},
                   "routing": {}, "elasticity": {}})
    problems = check_report(path, smoke_run=True)
    assert sorted(problems) == [
        "BENCH_ingress.json: missing required key 'dropped_requests'",
        "BENCH_ingress.json: missing required key 'token_identical'"]
