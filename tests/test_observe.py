"""The observability plane (serving/observe.py) and its wiring: span
trees for every completion, the in-repo Prometheus registry + strict
text-format validator (the CI conformance gate), the control-plane
flight recorder, and the instrumentation satellites (telemetry
serialization drift, re-entrant host-sync counting, bounded fault
windows).

Tier-1 covers the pure machinery plus local-instance end-to-end traces
through a real Ingress; the ``slow``-marked tests at the bottom spawn
real engine-server processes to prove cross-process trace propagation
with clock-skew correction and scrape a live multi-process pod's
``/metrics`` through the validator.
"""
import json
import os
import socket
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import instrument as INS
from repro.serving import observe as OBS
from repro.serving.request import RequestSpec
from repro.serving.ingress import Ingress
from repro.serving.orchestrator import Orchestrator

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama-1.1b").reduced()
    return cfg, T.init_params(cfg, KEY, "float32")


@pytest.fixture(scope="module")
def served(tiny):
    cfg, params = tiny
    orch = Orchestrator(cfg, params, n_instances=2, max_batch=2,
                        max_len=64, block_size=8, max_queue=4)
    ing = Ingress(orch, model_id="tiny-test").start()
    yield orch, ing
    ing.close()
    orch.close()


# ----------------------------------------------------- raw-socket client
def _request(ing, method, path, body=None):
    s = socket.create_connection(("127.0.0.1", ing.port), timeout=60)
    payload = b"" if body is None else json.dumps(body).encode()
    raw = f"{method} {path} HTTP/1.1\r\nHost: t\r\n".encode()
    if payload:
        raw += b"Content-Type: application/json\r\n"
        raw += b"Content-Length: %d\r\n" % len(payload)
    raw += b"\r\n" + payload
    s.sendall(raw)
    data = b""
    while chunk := s.recv(65536):
        data += chunk
    s.close()
    head, _, body = data.partition(b"\r\n\r\n")
    lines = head.decode("latin1").split("\r\n")
    status = int(lines[0].split(" ")[1])
    headers = {}
    for ln in lines[1:]:
        k, _, v = ln.partition(":")
        headers[k.strip().lower()] = v.strip()
    return status, headers, body


def _finished_record(tracer, trace_id):
    for rec in tracer.finished:
        if rec["trace_id"] == trace_id:
            return rec
    raise AssertionError(f"trace {trace_id} never finished; "
                         f"have {[r['trace_id'] for r in tracer.finished]}")


# ======================================================= span primitives
def test_span_tree_ok_accepts_a_sound_tree():
    t0 = OBS.server_now()
    root = OBS.make_span("t1", "request", t0, t0 + 1.0, span_id="t1")
    root["parent"] = None
    pre = OBS.make_span("t1", "prefill", t0 + 0.1, t0 + 0.4)
    chunk = OBS.make_span("t1", "prefill_chunk", t0 + 0.1, t0 + 0.2,
                          parent=pre["id"])
    assert OBS.span_tree_ok([root, pre, chunk]) is None


def test_span_tree_ok_reports_violations():
    t0 = 100.0
    root = OBS.make_span("t", "request", t0, t0 + 1.0, span_id="t")
    root["parent"] = None

    assert "empty" in OBS.span_tree_ok([])
    two = dict(root, id="t2")
    assert "2 roots" in OBS.span_tree_ok([root, two])
    orphan = OBS.make_span("t", "x", t0 + 0.1, t0 + 0.2, parent="nope")
    assert "orphan" in OBS.span_tree_ok([root, orphan])
    open_ = OBS.make_span("t", "decode", t0 + 0.1)
    assert "never closed" in OBS.span_tree_ok([root, open_])
    backwards = OBS.make_span("t", "x", t0 + 0.5, t0 + 0.1)
    assert "before it starts" in OBS.span_tree_ok([root, backwards])
    outside = OBS.make_span("t", "x", t0 + 0.5, t0 + 2.0)
    assert "outside root" in OBS.span_tree_ok([root, outside])


def test_estimate_clock_offset_recovers_injected_skew():
    skew = 5.0

    def call():
        time.sleep(0.001)            # symmetric fake RTT
        ts = time.monotonic() + skew
        time.sleep(0.001)
        return ts

    off = OBS.estimate_clock_offset(call, samples=5)
    assert abs(off - skew) < 0.05
    spans = [OBS.make_span("t", "x", 10.0, 11.0),
             OBS.make_span("t", "open", 10.0)]
    OBS.correct_spans(spans, 5.0)
    assert spans[0]["t0"] == 5.0 and spans[0]["t1"] == 6.0
    assert spans[1]["t1"] is None    # open spans shift t0 only


def test_tracer_lifecycle_and_jsonl_export(tmp_path):
    out = tmp_path / "traces.jsonl"
    tr = OBS.Tracer(out_path=str(out))
    t0 = OBS.server_now()
    tid = tr.begin(7, t0=t0, prompt_tokens=4)
    assert tid.startswith("req-7-")
    assert tr.ctx(7) == {"trace_id": tid, "rid": 7}
    assert tr.trace_id(7) == tid
    assert tr.live_rids() == [7]

    tr.span(7, "route", t0, attrs={"instance": 1})
    eng = OBS.make_span(tid, "decode", OBS.server_now(), OBS.server_now(),
                        origin="local")
    tr.ingest([eng])
    # spans for a trace nobody began are counted, never raised
    tr.span(99, "route", t0)
    tr.ingest([OBS.make_span("req-unknown", "x", t0, t0)])
    assert tr.dropped_spans == 2

    rec = tr.finish(7, tokens=3)
    assert rec["trace_id"] == tid
    assert OBS.span_tree_ok(rec["spans"]) is None
    assert rec["spans"][0]["attrs"] == {"prompt_tokens": 4, "tokens": 3}
    assert tr.live_rids() == [] and tr.ctx(7) is None
    assert tr.finish(7) is None      # double finish: no-op
    # a second trace, then read the JSONL sink back
    tr.begin(8)
    tr.finish(8)
    tr.close()
    lines = [json.loads(ln) for ln in out.read_text().splitlines()]
    assert tr.exported == 2 and len(lines) == 2
    assert lines[0]["trace_id"] == tid
    assert {s["name"] for s in lines[0]["spans"]} == \
        {"request", "route", "decode"}


def test_engine_span_recorder_records_only_registered_rids():
    rec = OBS.EngineSpanRecorder(origin="unit")

    class R:
        rid = 1

    rec.on_submit(R)                 # unregistered: dict miss, no span
    assert rec.drain() == []
    rec.register(1, "tid")
    rec.on_submit(R)
    rec.on_chunk(1, 0, 8, rec.now(), rec.now())
    rec.on_activate(R, fresh_first=True)
    rec.on_finish(R)
    names = [s["name"] for s in rec.drain()]
    assert names.count("prefill_chunk") == 1
    assert {"queue", "prefill", "first_token", "decode"} <= set(names)
    assert rec.drain() == []         # drained means drained


def test_flight_recorder_ring_dump_and_auto_dump(tmp_path):
    fr = OBS.FlightRecorder(capacity=4)
    for i in range(6):
        fr.record("route", idx=i % 2)
    evts = fr.events()
    assert len(evts) == 4            # ring dropped the 2 oldest
    assert [e["seq"] for e in evts] == [3, 4, 5, 6]
    assert len(fr.events("route")) == 4 and fr.events("nope") == []
    d = fr.dump()
    assert d["capacity"] == 4 and d["recorded"] == 6
    assert fr.auto_dump("no path configured") is None

    path = tmp_path / "flightrec.json"
    fr2 = OBS.FlightRecorder(capacity=8, dump_path=str(path))
    fr2.record("quarantine", instance=1)
    assert fr2.auto_dump("crash_recovery:test") == str(path)
    assert fr2.dumps == 1
    payload = json.loads(path.read_text())
    assert payload["reason"] == "crash_recovery:test"
    assert payload["events"][0]["kind"] == "quarantine"


# ================================================ Prometheus exposition
def test_registry_renders_conformant_exposition():
    reg = OBS.MetricsRegistry()
    reg.counter("repro_requests_total", "Accepted completions.", 12)
    reg.counter("repro_routed_total", "By reason.", 9,
                labels={"reason": "prefix"})
    reg.counter("repro_routed_total", "By reason.", 3,
                labels={"reason": "vacancy"})
    reg.gauge("repro_queue_depth", "Queue depth.", 2, labels={"instance": 0})
    reg.gauge("repro_weird", "Escaping.", 1,
              labels={"path": 'a"b\\c\nd'})
    reg.histogram("repro_itl_seconds", "Inter-token latency.",
                  [0.004, 0.009, 0.05], buckets=(0.005, 0.01),
                  labels={"instance": 0})
    text = reg.render()
    fams = OBS.parse_prometheus(text)
    assert fams["repro_requests_total"]["type"] == "counter"
    assert fams["repro_requests_total"]["samples"][0][2] == 12.0
    routed = {s[1]["reason"]: s[2]
              for s in fams["repro_routed_total"]["samples"]}
    assert routed == {"prefix": 9.0, "vacancy": 3.0}
    # label escaping survives the round trip
    weird = fams["repro_weird"]["samples"][0][1]["path"]
    assert weird == 'a"b\\c\nd'
    hist = {s[0]: s for s in fams["repro_itl_seconds"]["samples"]}
    buckets = {s[1]["le"]: s[2]
               for s in fams["repro_itl_seconds"]["samples"]
               if s[0] == "repro_itl_seconds_bucket"}
    assert buckets == {"0.005": 1.0, "0.01": 2.0, "+Inf": 3.0}
    assert hist["repro_itl_seconds_count"][2] == 3.0


def test_registry_rejects_bad_input():
    reg = OBS.MetricsRegistry()
    with pytest.raises(ValueError):
        reg.counter("bad-name", "h", 1)
    with pytest.raises(ValueError):
        reg.counter("ok_name", "h", 1, labels={"bad-label": "x"})
    reg.counter("repro_x", "h", 1)
    with pytest.raises(ValueError):
        reg.gauge("repro_x", "h", 1)      # type redeclaration


@pytest.mark.parametrize("text,needle", [
    ("repro_x 1\n", "no # TYPE"),
    ("# TYPE repro_x counter\nrepro_x 1\n# TYPE repro_x counter\n",
     "duplicate TYPE"),
    ("# TYPE repro_x counter\nrepro_x 1\n# TYPE repro_x gauge\n",
     "duplicate TYPE"),
    ("# HELP repro_x h\nrepro_x 1\n# TYPE repro_x counter\n",
     "after its samples"),
    ("# HELP repro_x h\n", "HELP without TYPE"),
    ("# TYPE repro_x counter\nrepro_x{le=1} 3\n", "bad label"),
    ("# TYPE repro_x counter\nrepro_x abc\n", "bad value"),
    ("# TYPE repro_x counter\nrepro_x 1 soon\n", "bad timestamp"),
    ("# TYPE repro_x wat\n", "bad type"),
    # histogram structure: no +Inf / non-cumulative / _count mismatch
    ('# TYPE repro_h histogram\nrepro_h_bucket{le="1"} 2\n'
     "repro_h_sum 2\nrepro_h_count 2\n", "no +Inf"),
    ('# TYPE repro_h histogram\nrepro_h_bucket{le="1"} 5\n'
     'repro_h_bucket{le="+Inf"} 3\nrepro_h_sum 2\nrepro_h_count 3\n',
     "not cumulative"),
    ('# TYPE repro_h histogram\nrepro_h_bucket{le="1"} 1\n'
     'repro_h_bucket{le="+Inf"} 3\nrepro_h_sum 2\nrepro_h_count 7\n',
     "+Inf bucket"),
])
def test_parser_rejects_malformed_exposition(text, needle):
    with pytest.raises(ValueError) as ei:
        OBS.parse_prometheus(text)
    assert needle in str(ei.value), (needle, str(ei.value))


# =========================================== instrumentation satellites
def test_telemetry_state_covers_every_gauge():
    """Serialization drift gate: a gauge added to EngineTelemetry MUST
    be added to to_state/load_state in the same change, or the remote
    plane silently reports stale zeros for it. ``vars()`` is the live
    attribute set; the wire schema must be exactly that plus the window
    size."""
    tel = INS.EngineTelemetry()
    assert set(tel.to_state()) == set(vars(tel)) | {"window"}


def test_telemetry_round_trip_is_lossless():
    src = INS.EngineTelemetry(window=8)
    for i in range(12):              # overflow the window: maxlen rides
        src.record_step(0.01 * (i + 1), i, packed=i, budget=32)

    class _R:
        def __init__(self, i):
            self.submit_time = 0.0
            self.first_token_time = 0.5 + i
            self.finish_time = 2.0 + i
            self.prefill_start_time = 0.25

    src.record_finished([_R(0), _R(1)])
    src.record_preemptions(3)
    src.record_prefix(10, 7, 4)

    dst = INS.EngineTelemetry()
    dst.load_state(src.to_state())
    assert dst.to_state() == src.to_state()
    assert dst.step_seconds.maxlen == 8
    assert dst.tokens_per_s() == src.tokens_per_s()
    assert dst.budget_utilization() == src.budget_utilization()
    assert dst.prefix_hit_rate() == src.prefix_hit_rate()


def test_fault_detect_latencies_window_is_bounded():
    fc = INS.FaultCounters()
    for i in range(600):
        fc.detect_latencies.append(float(i))
    assert len(fc.detect_latencies) == 512
    assert fc.detect_latencies[0] == 88.0       # oldest evicted
    assert fc.detect_quantile(1.0) == 599.0


def test_count_host_syncs_nested_and_threaded():
    orig = jax.device_get
    x = np.zeros(1)
    with INS.count_host_syncs() as outer:
        jax.device_get(x)
        with INS.count_host_syncs() as inner:
            jax.device_get(x)
        jax.device_get(x)
    assert (outer.n, inner.n) == (3, 1)
    assert jax.device_get is orig   # outermost exit restored the original

    # two concurrent contexts: each counts every sync in its window and
    # the LAST one out restores the original (no wrapper left behind)
    counts = []
    gate = threading.Barrier(2)

    def worker():
        with INS.count_host_syncs() as c:
            gate.wait()
            jax.device_get(x)
            gate.wait()
            counts.append(c.n)

    ts = [threading.Thread(target=worker) for _ in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert counts == [2, 2]
    assert jax.device_get is orig


# ============================================ end-to-end traces (local)
def test_local_completion_produces_connected_trace(tiny):
    cfg, params = tiny
    orch = Orchestrator(cfg, params, n_instances=2, max_batch=2,
                        max_len=64, block_size=8,
                        tracer=OBS.Tracer(), telemetry_every=10_000)
    reqs = [RequestSpec(rid=i,
                        prompt=np.arange(2 + i, 12 + i, dtype=np.int32),
                        max_tokens=6) for i in range(3)]
    for r in reqs:
        orch.tracer.begin(r.rid, prompt_tokens=len(r.prompt))
        orch.submit(r)
    orch.run_until_done()
    assert orch.tracer.live_rids() == []         # every trace closed
    assert len(orch.tracer.finished) == 3
    for rec in orch.tracer.finished:
        err = OBS.span_tree_ok(rec["spans"])
        assert err is None, err
        names = {s["name"] for s in rec["spans"]}
        assert {"request", "queue", "prefill", "first_token",
                "decode"} <= names, names
        assert rec["spans"][0]["attrs"]["tokens"] == 6
    assert orch.tracer.dropped_spans == 0
    orch.close()


def test_mid_decode_migration_appends_hop_span(tiny):
    cfg, params = tiny
    orch = Orchestrator(cfg, params, n_instances=2, max_batch=2,
                        max_len=64, block_size=8, n_blocks=24,
                        tracer=OBS.Tracer(), telemetry_every=10_000)
    req = RequestSpec(rid=0, prompt=np.arange(2, 12, dtype=np.int32),
                      max_tokens=10)
    orch.tracer.begin(req.rid)
    orch.submit_to(0, req)
    for _ in range(4):
        orch.step()
    live = next(r for r in orch.engines[0].active.values() if r.rid == 0)
    assert len(live.generated) >= 2              # mid-decode
    recs = orch.migrate_requests(0, 1)
    assert len(recs) == 1 and recs[0].resumed
    orch.run_until_done()

    rec = orch.tracer.finished[-1]
    err = OBS.span_tree_ok(rec["spans"])
    assert err is None, err                      # tree stays connected
    hops = [s for s in rec["spans"] if s["name"] == "migration_hop"]
    assert len(hops) == 1
    assert hops[0]["attrs"]["src"] == 0 and hops[0]["attrs"]["dst"] == 1
    # the source closed its decode span at the pause; the destination
    # opened its own continuation — both halves are in the tree
    decodes = [s for s in rec["spans"] if s["name"] == "decode"]
    assert len(decodes) == 2
    assert any(s["attrs"].get("paused") for s in decodes)
    # and only ONE first_token: the continuation did not re-emit it
    assert len([s for s in rec["spans"]
                if s["name"] == "first_token"]) == 1
    # the flight recorder kept the migration's phase evidence
    evts = orch.flightrec.events("migration")
    assert len(evts) == 1 and evts[0]["rid"] == 0
    assert evts[0]["bytes_moved"] > 0
    orch.close()


def test_flight_recorder_captures_controller_inputs(tiny):
    cfg, params = tiny
    orch = Orchestrator(cfg, params, n_instances=2, max_batch=2,
                        max_len=64, block_size=8, telemetry_every=10_000)
    req = RequestSpec(rid=0, prompt=np.arange(2, 10, dtype=np.int32),
                      max_tokens=4)
    orch.submit(req)
    orch.run_until_done()
    orch.control_tick()
    evts = orch.flightrec.events("controller")
    assert evts, "control_tick recorded no decision"
    inputs = evts[-1]["inputs"]
    assert {"slo_violation_rate", "queue_len", "tokens_per_s",
            "pod_size"} <= set(inputs)
    orch.close()


# ====================================== ingress: tracing + /metrics
def test_unary_completion_carries_request_id_and_trace(served):
    orch, ing = served
    status, headers, body = _request(
        ing, "POST", "/v1/completions",
        body={"prompt": [5, 6, 7, 8], "max_tokens": 4})
    assert status == 200
    tid = headers["x-request-id"]
    assert tid.startswith("req-")
    rec = _finished_record(ing.tracer, tid)
    err = OBS.span_tree_ok(rec["spans"])
    assert err is None, err
    names = {s["name"] for s in rec["spans"]}
    assert {"request", "accept", "route", "queue", "prefill",
            "first_token", "decode"} <= names, names
    route = next(s for s in rec["spans"] if s["name"] == "route")
    assert route["attrs"]["reason"] in ("prefix", "vacancy")
    assert rec["spans"][0]["attrs"]["tokens"] == 4


def test_stream_completion_carries_request_id_and_trace(served):
    orch, ing = served
    status, headers, body = _request(
        ing, "POST", "/v1/completions",
        body={"prompt": "trace me", "max_tokens": 4, "stream": True})
    assert status == 200
    assert headers["content-type"].startswith("text/event-stream")
    tid = headers["x-request-id"]
    assert b"[DONE]" in body
    rec = _finished_record(ing.tracer, tid)
    err = OBS.span_tree_ok(rec["spans"])
    assert err is None, err


def test_metrics_endpoint_is_conformant_and_moves(served):
    orch, ing = served
    status, headers, text = _request(ing, "GET", "/metrics")
    assert status == 200
    assert headers["content-type"].startswith("text/plain; version=0.0.4")
    fams = OBS.parse_prometheus(text.decode())
    required = {"repro_requests_total", "repro_http_429_total",
                "repro_bad_requests_total", "repro_tokens_out_total",
                "repro_routed_total", "repro_tokens_per_s",
                "repro_budget_utilization", "repro_prefix_hit_rate",
                "repro_pod_size", "repro_faults_total",
                "repro_instance_up", "repro_queue_depth",
                "repro_block_vacancy", "repro_ttft_steps",
                "repro_itl_seconds", "repro_traces_exported_total",
                "repro_trace_spans_dropped_total",
                "repro_flightrec_events"}
    assert required <= set(fams), required - set(fams)
    # per-instance labels for both pod members
    up = {s[1]["instance"] for s in fams["repro_instance_up"]["samples"]}
    assert up == {"0", "1"}

    def counter(fams, name):
        return fams[name]["samples"][0][2]

    before = counter(fams, "repro_requests_total")
    _request(ing, "POST", "/v1/completions",
             body={"prompt": [9, 9, 9], "max_tokens": 2})
    _, _, text = _request(ing, "GET", "/metrics")
    fams = OBS.parse_prometheus(text.decode())
    assert counter(fams, "repro_requests_total") == before + 1
    assert counter(fams, "repro_tokens_out_total") > 0
    assert counter(fams, "repro_trace_spans_dropped_total") == 0


def test_flightrec_endpoint_serves_routing_verdicts(served):
    orch, ing = served
    _request(ing, "POST", "/v1/completions",
             body={"prompt": [3, 1, 4, 1, 5], "max_tokens": 2})
    status, headers, body = _request(ing, "GET", "/debug/flightrec")
    assert status == 200
    dump = json.loads(body)
    assert dump["capacity"] == 512 and dump["recorded"] >= 1
    routes = [e for e in dump["events"] if e["kind"] == "route"]
    assert routes and routes[-1]["verdict"] == "admit"
    assert routes[-1]["reason"] in ("prefix", "vacancy")
    assert {"seq", "t", "wall"} <= set(routes[-1])


def test_single_sync_invariant_holds_under_ingress_pump(served):
    """The host-sync counter is safe while the pump thread steps real
    engines concurrently (the process-wide patch, not save/restore),
    and the plane stays within the paged-engine bound: at most one
    blocking device->host sync per engine step, fleet-wide."""
    orch, ing = served
    orig = jax.device_get
    ticks0 = orch.rpc_stats["ticks"]
    with INS.count_host_syncs() as c:
        status, _, _ = _request(
            ing, "POST", "/v1/completions",
            body={"prompt": [2, 7, 1, 8], "max_tokens": 4})
        assert status == 200
        ticks1 = orch.rpc_stats["ticks"]
    assert jax.device_get is orig
    assert c.n >= 1, "the pump's engine steps were not counted"
    n_inst = len(orch.instances)
    # +1 tick of slack: the pump may be mid-step at either read
    assert c.n <= (ticks1 - ticks0 + 1) * n_inst, \
        (c.n, ticks1 - ticks0, n_inst)


# ==================================== cross-process (tier-2: spawned)
@pytest.mark.slow
def test_remote_trace_skew_corrected_over_tcp(tiny):
    """A spawned TCP engine server with an injected 7.5s clock skew:
    the proxy's RTT offset estimate recovers the skew, ingestion shifts
    the server-stamped spans back onto the ingress clock, and the
    finished trace is one connected tree with every engine span inside
    the root window — which cannot hold if correction is off by the
    injected amount."""
    cfg, params = tiny
    os.environ[OBS._SKEW_ENV] = "7.5"
    os.environ["REPRO_RPC_TRANSPORT"] = "tcp"
    try:
        orch = Orchestrator(cfg, params, n_instances=1, max_batch=2,
                            max_len=64, block_size=8, remote=True,
                            tracer=OBS.Tracer(), telemetry_every=10_000)
    finally:
        # the parent must NOT run skewed: only the spawned server
        # (which inherited the env) reports a shifted server_now()
        del os.environ[OBS._SKEW_ENV]
        del os.environ["REPRO_RPC_TRANSPORT"]
    try:
        assert abs(orch.instances[0].clock_offset - 7.5) < 1.0
        req = RequestSpec(rid=0, prompt=np.arange(2, 12, dtype=np.int32),
                          max_tokens=6)
        orch.tracer.begin(req.rid)
        orch.submit(req)
        orch.run_until_done()
        rec = orch.tracer.finished[-1]
        err = OBS.span_tree_ok(rec["spans"])
        assert err is None, err
        remote = [s for s in rec["spans"]
                  if s["origin"].startswith("server:")]
        assert remote, "no engine-server spans arrived"
        assert {"queue", "prefill", "decode"} <= \
            {s["name"] for s in remote}
        assert orch.tracer.dropped_spans == 0
    finally:
        orch.close()


@pytest.mark.slow
def test_live_pod_metrics_scrape_is_conformant(tiny):
    """The CI nightly conformance gate: scrape a REAL 2-worker
    multi-process pod's /metrics through the strict validator."""
    cfg, params = tiny
    orch = Orchestrator(cfg, params, n_instances=2, max_batch=2,
                        max_len=64, block_size=8, remote=True)
    ing = Ingress(orch, model_id="tiny-pod").start()
    try:
        for k in range(3):
            status, headers, _ = _request(
                ing, "POST", "/v1/completions",
                body={"prompt": [5 + k, 6, 7], "max_tokens": 3})
            assert status == 200
            assert "x-request-id" in headers
        status, headers, text = _request(ing, "GET", "/metrics")
        assert status == 200
        fams = OBS.parse_prometheus(text.decode())
        up = {s[1]["instance"]: s[2]
              for s in fams["repro_instance_up"]["samples"]}
        assert up == {"0": 1.0, "1": 1.0}
        assert fams["repro_tokens_out_total"]["samples"][0][2] >= 9
        assert fams["repro_pod_size"]["samples"][0][2] == 2
        # every completion over the RPC plane closed a connected trace
        assert len(ing.tracer.finished) == 3
        for rec in ing.tracer.finished:
            err = OBS.span_tree_ok(rec["spans"])
            assert err is None, err
    finally:
        ing.close()
        orch.close()
