"""The HTTP front door (serving/ingress.py), exercised the hard way: raw
sockets and hand-parsed HTTP/1.1 — no client library — so the framing
itself (status lines, Content-Length, chunked transfer encoding) is
under test, not just the payloads. Covers: response framing, streamed
chunk ordering, deterministic 429 backpressure (the ``hold_pump`` test
hook), malformed-request 400s, and graceful shutdown mid-stream."""
import json
import socket
import time

import jax
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving.ingress import Ingress, byte_tokens
from repro.serving.orchestrator import Orchestrator

KEY = jax.random.PRNGKey(0)
MAX_QUEUE = 2


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama-1.1b").reduced()
    return cfg, T.init_params(cfg, KEY, "float32")


@pytest.fixture(scope="module")
def served(tiny):
    cfg, params = tiny
    orch = Orchestrator(cfg, params, n_instances=2, max_batch=2,
                        max_len=64, block_size=8, max_queue=MAX_QUEUE)
    ing = Ingress(orch, model_id="tiny-test").start()
    yield orch, ing
    ing.close()
    orch.close()


# ----------------------------------------------------- raw-socket client
def _connect(ing):
    return socket.create_connection(("127.0.0.1", ing.port), timeout=60)


def _send(sock, method, path, body=None, raw=None):
    if raw is None:
        payload = b"" if body is None else json.dumps(body).encode()
        raw = f"{method} {path} HTTP/1.1\r\nHost: t\r\n".encode()
        if payload:
            raw += b"Content-Type: application/json\r\n"
            raw += b"Content-Length: %d\r\n" % len(payload)
        raw += b"\r\n" + payload
    sock.sendall(raw)


def _recv_all(sock):
    data = b""
    while chunk := sock.recv(65536):
        data += chunk
    return data


def _parse(data):
    """Strict HTTP/1.1 response parse: (status, headers, raw body)."""
    head, _, body = data.partition(b"\r\n\r\n")
    lines = head.decode("latin1").split("\r\n")
    proto, status, *_ = lines[0].split(" ")
    assert proto == "HTTP/1.1"
    headers = {}
    for ln in lines[1:]:
        k, _, v = ln.partition(":")
        headers[k.strip().lower()] = v.strip()
    return int(status), headers, body


def _parse_chunked(body):
    """Decode chunked transfer encoding STRICTLY; returns (payloads,
    saw_terminator). Any framing slip (bad size line, missing CRLF)
    fails the test here rather than being papered over."""
    chunks, rest, done = [], body, False
    while rest:
        size_line, _, rest = rest.partition(b"\r\n")
        size = int(size_line, 16)
        if size == 0:
            assert rest[:2] in (b"\r\n", b"")   # final CRLF
            done = True
            break
        assert len(rest) >= size + 2, "truncated chunk"
        chunks.append(rest[:size])
        assert rest[size:size + 2] == b"\r\n"
        rest = rest[size + 2:]
    return chunks, done


def _request(ing, method, path, body=None, raw=None):
    s = _connect(ing)
    _send(s, method, path, body=body, raw=raw)
    data = _recv_all(s)
    s.close()
    return _parse(data)


# ------------------------------------------------------------- framing
def test_health_and_models_framing(served):
    _, ing = served
    status, headers, body = _request(ing, "GET", "/healthz")
    assert status == 200
    assert int(headers["content-length"]) == len(body)
    assert headers["content-type"] == "application/json"
    assert headers["connection"] == "close"
    obj = json.loads(body)
    assert obj["status"] == "ok" and obj["pod_size"] == 2

    status, headers, body = _request(ing, "GET", "/v1/models")
    assert status == 200
    assert int(headers["content-length"]) == len(body)
    assert json.loads(body)["data"][0]["id"] == "tiny-test"


def test_stats_surfaces_snapshot_and_counters(served):
    _, ing = served
    status, _, body = _request(ing, "GET", "/stats")
    assert status == 200
    obj = json.loads(body)
    assert set(obj) >= {"snapshot", "ingress", "pod", "finished",
                        "dropped"}
    assert obj["snapshot"]["pod_size"] == 2
    assert set(obj["ingress"]) >= {"requests", "rejected_429",
                                   "tokens_out", "routed_prefix",
                                   "routed_vacancy"}


def test_byte_tokenizer_is_deterministic(tiny):
    cfg, _ = tiny
    a, b = byte_tokens("same text", cfg.vocab_size), \
        byte_tokens("same text", cfg.vocab_size)
    assert (a == b).all() and len(a) == len("same text")
    assert (a >= 2).all() and (a < cfg.vocab_size).all()


# ---------------------------------------------------------- completions
def test_unary_completion(served):
    _, ing = served
    status, headers, body = _request(
        ing, "POST", "/v1/completions",
        body={"prompt": [5, 6, 7, 8], "max_tokens": 4})
    assert status == 200
    assert int(headers["content-length"]) == len(body)
    obj = json.loads(body)
    assert len(obj["tokens"]) == 4
    assert obj["usage"]["completion_tokens"] == 4
    assert obj["routing"]["reason"] in ("prefix", "vacancy")
    assert obj["routing"]["instance"] in (0, 1)


def test_streaming_chunk_framing_and_order(served):
    _, ing = served
    status, headers, body = _request(
        ing, "POST", "/v1/completions",
        body={"prompt": "stream me please", "max_tokens": 6,
              "stream": True})
    assert status == 200
    assert headers["transfer-encoding"] == "chunked"
    assert headers["content-type"] == "text/event-stream"
    assert "content-length" not in headers
    chunks, terminated = _parse_chunked(body)
    assert terminated, "missing 0\\r\\n\\r\\n chunked terminator"
    events = []
    for c in chunks:
        assert c.startswith(b"data: ") and c.endswith(b"\n\n")
        events.append(c[len(b"data: "):].strip())
    # first event: the routing verdict; last: [DONE]; between: tokens
    # with strictly consecutive indices (order is the contract)
    head = json.loads(events[0])
    assert head["routing"] in ("prefix", "vacancy")
    assert events[-1] == b"[DONE]"
    toks = [json.loads(e) for e in events[1:-1]]
    assert [t["index"] for t in toks] == list(range(6))
    assert all(isinstance(t["token"], int) for t in toks)


def test_tokens_arrive_incrementally(served):
    """Streaming means streaming: at least one token chunk must be on
    the wire BEFORE the request finishes — observed as data arriving in
    more than one socket read with a gap between them."""
    _, ing = served
    s = _connect(ing)
    _send(s, "POST", "/v1/completions",
          body={"prompt": "incremental", "max_tokens": 8, "stream": True})
    reads = []
    while True:
        chunk = s.recv(65536)
        if not chunk:
            break
        reads.append((time.monotonic(), chunk))
    s.close()
    assert len(reads) > 1, "entire stream arrived in one flush"
    body = b"".join(c for _, c in reads).partition(b"\r\n\r\n")[2]
    _, terminated = _parse_chunked(body)
    assert terminated


# ------------------------------------------------------- backpressure
def test_deterministic_429_and_recovery(served):
    """With the pump held, accepted-but-unpumped requests fill every
    seat (2 instances x max_queue) and the next admission is shed with
    429 + Retry-After; releasing the pump drains the backlog and every
    held request completes."""
    _, ing = served
    seats = 2 * MAX_QUEUE
    base = ing.counters.requests
    ing.hold_pump.set()
    socks = []
    try:
        for k in range(seats):
            s = _connect(ing)
            _send(s, "POST", "/v1/completions",
                  body={"prompt": [10 + k], "max_tokens": 2})
            socks.append(s)
        deadline = time.monotonic() + 30
        while ing.counters.requests < base + seats:
            assert time.monotonic() < deadline, "accepts not registered"
            time.sleep(0.01)
        status, headers, body = _request(
            ing, "POST", "/v1/completions",
            body={"prompt": [99], "max_tokens": 2})
        assert status == 429
        assert headers["retry-after"] == "1"
        assert json.loads(body)["error"]
        assert ing.counters.rejected_429 >= 1
    finally:
        ing.hold_pump.clear()
    for s in socks:
        status, _, body = _parse(_recv_all(s))
        s.close()
        assert status == 200
        assert len(json.loads(body)["tokens"]) == 2


# ------------------------------------------------------------- rejects
@pytest.mark.parametrize("body", [
    {"max_tokens": 4},                          # no prompt
    {"prompt": ""},                             # empty text
    {"prompt": []},                             # empty ids
    {"prompt": [1, -2, 3]},                     # negative id
    {"prompt": [1, "x"]},                       # non-int id
    {"prompt": [1, 2], "max_tokens": 0},        # out-of-range knobs
    {"prompt": [1, 2], "max_tokens": 99999},
    {"prompt": [1, 2], "temperature": "hot"},
])
def test_malformed_completions_get_400(served, body):
    _, ing = served
    status, _, resp = _request(ing, "POST", "/v1/completions", body=body)
    assert status == 400
    assert json.loads(resp)["error"]


def test_broken_http_framing_gets_400(served):
    _, ing = served
    for raw in [b"GARBAGE\r\n\r\n",
                b"GET /healthz\r\n\r\n",              # no HTTP version
                b"POST /v1/completions HTTP/1.1\r\n"
                b"Content-Length: 99999999\r\n\r\n",  # absurd length
                b"POST /v1/completions HTTP/1.1\r\n"
                b"Content-Length: 4\r\n\r\nnot-"]:    # non-JSON body
        status, _, _ = _request(ing, "POST", "/x", raw=raw)
        assert status == 400


def test_unknown_path_404_and_wrong_method_405(served):
    _, ing = served
    assert _request(ing, "GET", "/nope")[0] == 404
    assert _request(ing, "GET", "/v1/completions")[0] == 405
    # GET-only routes don't match under POST -> falls through to 404
    assert _request(ing, "POST", "/healthz", body={"x": 1})[0] == 404


# ----------------------------------------------------- graceful shutdown
def test_graceful_shutdown_mid_stream(tiny):
    """close() during an in-flight stream must leave the client a WELL-
    FORMED tail: an error event, then the zero-length chunk terminator —
    never a connection reset mid-chunk. New intake gets 503."""
    cfg, params = tiny
    orch = Orchestrator(cfg, params, n_instances=1, max_batch=2,
                        max_len=64, block_size=8)
    ing = Ingress(orch).start()
    s = _connect(ing)
    _send(s, "POST", "/v1/completions",
          body={"prompt": "long running stream", "max_tokens": 256,
                "stream": True})
    # wait for the stream to be genuinely in flight (headers + routing
    # event on the wire), then shut down under it
    first = s.recv(65536)
    assert b"200 OK" in first
    ing.close()
    tail = first + _recv_all(s)
    s.close()
    body = tail.partition(b"\r\n\r\n")[2]
    chunks, terminated = _parse_chunked(body)
    assert terminated, "shutdown must emit the chunked terminator"
    assert any(b"shutting down" in c for c in chunks)
    assert ing.counters.aborted_streams >= 1
    orch.close()


def test_closing_ingress_rejects_new_intake_with_503(tiny):
    cfg, params = tiny
    orch = Orchestrator(cfg, params, n_instances=1, max_batch=2,
                        max_len=64, block_size=8)
    ing = Ingress(orch).start()
    ing._closing = True        # the first thing close() sets
    status, _, body = _request(ing, "GET", "/healthz")
    assert status == 503 and b"shutting down" in body
    ing._closing = False
    ing.close()
    orch.close()


# ------------------------------------------- SLO fields + 400 taxonomy
def test_slo_completion_accepted_end_to_end(served):
    """A body carrying slo_class + deadline_ms is parsed into the
    RequestSpec and served normally — the fields are additive, not a
    different endpoint."""
    _, ing = served
    status, _, resp = _request(
        ing, "POST", "/v1/completions",
        body={"prompt": "hello slo", "max_tokens": 4,
              "slo_class": "interactive", "deadline_ms": 2000})
    assert status == 200
    out = json.loads(resp)
    assert len(out["tokens"]) == 4
    assert out["usage"]["completion_tokens"] == 4


def test_unknown_slo_class_gets_typed_400(served):
    _, ing = served
    status, _, resp = _request(
        ing, "POST", "/v1/completions",
        body={"prompt": "x", "slo_class": "platinum"})
    assert status == 400
    out = json.loads(resp)
    assert out["error"] == "unknown_slo_class"
    assert "platinum" in out["detail"]
    assert "interactive" in out["detail"]       # the menu is in the body


def test_bad_deadline_gets_typed_400(served):
    _, ing = served
    for bad in (0, -5, -0.1):
        status, _, resp = _request(
            ing, "POST", "/v1/completions",
            body={"prompt": "x", "deadline_ms": bad})
        assert status == 400
        out = json.loads(resp)
        assert out["error"] == "bad_deadline"
        assert "deadline_ms" in out["detail"]


def test_unknown_body_fields_get_typed_400(served):
    _, ing = served
    status, _, resp = _request(
        ing, "POST", "/v1/completions",
        body={"prompt": "x", "slo": "interactive", "maxTokens": 4})
    assert status == 400
    out = json.loads(resp)
    assert out["error"] == "unknown_fields"
    assert sorted(out["fields"]) == ["maxTokens", "slo"]


def test_taxonomy_bodies_are_distinct(served):
    """The three typed rejections carry three distinct machine-readable
    codes — a client can branch without parsing prose."""
    _, ing = served
    codes = set()
    for body in ({"prompt": "x", "slo_class": "nope"},
                 {"prompt": "x", "deadline_ms": -1},
                 {"prompt": "x", "bogus_key": 1}):
        status, _, resp = _request(ing, "POST", "/v1/completions",
                                   body=body)
        assert status == 400
        codes.add(json.loads(resp)["error"])
    assert codes == {"unknown_slo_class", "bad_deadline",
                     "unknown_fields"}


# ------------------------------------------------- the budget governor
class _FakeInstance:
    def __init__(self):
        self.calls = []

    def set_token_budget(self, budget):
        self.calls.append(budget)
        return budget


class _FakeRec:
    def __init__(self):
        self.events = []

    def record(self, kind, **fields):
        self.events.append((kind, fields))


class _FakeOrch:
    def __init__(self, tel):
        self.telemetry = [tel]
        self.instances = [_FakeInstance()]
        self.flightrec = _FakeRec()

    def _alive(self):
        return [0]


def _saturated_telemetry(budget=128, packed=None, delay=10.0):
    from repro.serving.instrument import EngineTelemetry
    tel = EngineTelemetry()
    tel.budget = budget
    tel.packed_tokens.extend([packed if packed is not None else budget] * 8)
    tel.queue_delays.extend([delay] * 8)
    return tel


def test_budget_governor_grows_under_saturation_and_delay():
    from repro.serving.ingress import BudgetGovernor
    orch = _FakeOrch(_saturated_telemetry())
    gov = BudgetGovernor(orch, period_s=0.5)
    assert gov.tick(now=10.0)
    assert orch.instances[0].calls == [192]          # 128 * 1.5
    assert gov.budgets[0] == 192 and gov.adjustments == 1
    kind, ev = orch.flightrec.events[0]
    assert kind == "budget_governor"
    assert ev["budget"] == 192 and ev["prev"] == 128
    # rate limit: a second tick inside period_s is a no-op
    assert not gov.tick(now=10.2)
    assert gov.adjustments == 1


def test_budget_governor_shrinks_when_budget_rides_empty():
    from repro.serving.ingress import BudgetGovernor
    orch = _FakeOrch(_saturated_telemetry(budget=128, packed=16,
                                          delay=0.0))
    gov = BudgetGovernor(orch, period_s=0.0)
    assert gov.tick(now=1.0)
    assert orch.instances[0].calls == [96]           # 128 * 0.75
    # repeated shrink bottoms out at min_budget, then goes quiet
    for t in range(2, 20):
        gov.tick(now=float(t))
    assert gov.budgets[0] == gov.min_budget
    last = orch.instances[0].calls[-1]
    assert last == gov.min_budget
    n = gov.adjustments
    gov.tick(now=100.0)
    assert gov.adjustments == n                      # clamped: no churn


def test_budget_governor_skips_phase_engines_and_holds_steady_band():
    from repro.serving.instrument import EngineTelemetry
    from repro.serving.ingress import BudgetGovernor
    # phase engine: no budget, no packed window -> untouched
    orch = _FakeOrch(EngineTelemetry())
    gov = BudgetGovernor(orch, period_s=0.0)
    assert gov.tick(now=1.0)
    assert orch.instances[0].calls == []
    # mid-band utilization: saturated but NO queueing -> no grow either
    orch2 = _FakeOrch(_saturated_telemetry(delay=0.0))
    gov2 = BudgetGovernor(orch2, period_s=0.0)
    assert gov2.tick(now=1.0)
    assert orch2.instances[0].calls == []
