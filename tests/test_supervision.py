"""Failure-domain supervision (orchestrator + DESIGN.md §9), tier-1:
supervised respawn of a killed worker with capped backoff and
re-admission, the flap-detector circuit breaker, and hung-peer
classification — a half-open peer (real framed transport, a server
that reads and never replies) must be detected within ~2x the RPC
deadline, quarantined, and have its streams replayed token-identically
on a survivor. Uses in-process stand-ins so everything runs at tier-1
speed; the real multi-process plane is soaked by tests/test_chaos.py
and benchmarks/chaos_bench.py."""
import dataclasses
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import transport as TR
from repro.serving.engine import Engine
from repro.serving.request import RequestSpec, SamplingParams
from repro.serving.instance import InstanceHandle, LocalInstance, pristine
from repro.serving.instrument import EngineTelemetry
from repro.serving.orchestrator import Orchestrator, RespawnPolicy

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, KEY, "float32")
    return cfg, params


def _mk_engine(cfg, params):
    return Engine(cfg, params, max_batch=2, max_len=64,
                  cache_kind="paged", block_size=8, n_blocks=32)


def _reference(cfg, params, requests):
    """Failure-free oracle: each request solo on a fresh paged engine."""
    out = {}
    for r in requests:
        e = Engine(cfg, params, max_batch=1, max_len=64,
                   cache_kind="paged", block_size=8)
        e.submit(r)
        out[r.rid] = e.run_until_done()[0].generated
    return out


def _reqs(n, max_new=8):
    return [RequestSpec(rid=i,
                        prompt=np.arange(2 + i, 12 + i, dtype=np.int32),
                        max_tokens=max_new,
                        sampling=SamplingParams(temperature=0.7, top_k=8,
                                                seed=7 + i))
            for i in range(n)]


def _pump(orch, until, deadline_s=10.0):
    """Step the orchestrator until ``until()`` or the wall deadline —
    the supervisor only acts at step() boundaries, never in between."""
    t0 = time.monotonic()
    while not until() and time.monotonic() - t0 < deadline_s:
        time.sleep(0.02)
        orch.step()
    assert until(), "condition not reached within the pump deadline"


class RespawnableLocal(LocalInstance):
    """A LocalInstance that behaves like a worker process the
    orchestrator owns: it can die (``kill``), mirrors its inflight work
    for replay, and a factory stands in for the two-phase bring-up."""
    respawnable = True

    def __init__(self, engine, label, factory):
        super().__init__(engine)
        self.peer_label = label
        self._factory = factory
        self._dead = False

    def alive(self):
        return not self._dead

    def kill(self):
        self._dead = True

    def mark_dead(self):
        self._dead = True

    def inflight_requests(self):
        return ([pristine(r) for r in self.engine.queue]
                + [pristine(r) for r in self.engine.active.values()])

    def respawn(self, start_timeout=None):
        base, _, gen = self.peer_label.partition("~r")
        return self._factory(f"{base}~r{int(gen or 0) + 1}")


def _respawnable(cfg, params, label="w1"):
    def factory(new_label):
        return RespawnableLocal(_mk_engine(cfg, params), new_label,
                                factory)
    return RespawnableLocal(_mk_engine(cfg, params), label, factory)


# ------------------------------------------------------------- respawn
def test_killed_worker_is_replayed_respawned_and_readmitted(tiny):
    cfg, params = tiny
    local = LocalInstance(_mk_engine(cfg, params))
    worker = _respawnable(cfg, params)
    policy = RespawnPolicy(backoff_base=0.05, backoff_cap=0.1,
                           max_failures=3, window_s=10.0,
                           start_timeout=5.0)
    orch = Orchestrator(cfg, params, handles=[local, worker],
                        telemetry_every=10_000, respawn_policy=policy)
    reqs = _reqs(2)
    ref = _reference(cfg, params, reqs)
    # pin one stream on each instance, then kill the worker mid-flight
    for i, r in enumerate(reqs):
        orch.instances[i].submit(r)
        orch._home[r.rid] = i
    worker.kill()
    done = {r.rid: r.generated for r in orch.run_until_done()}
    # zero drop, token-identical: the kill cost recompute, never output
    assert sorted(done) == [0, 1]
    assert done == ref
    assert orch.recoveries[0]["reason"] == "dead"
    assert orch.recoveries[0]["rids"] == [1]
    # the supervisor swapped in a fresh incarnation under the same index
    _pump(orch, lambda: orch.faults.respawns == 1)
    fresh = orch.instances[1]
    assert fresh is not worker
    assert fresh.peer_label == "w1~r1"
    spawned = [e for e in orch.respawn_log if e["event"] == "respawned"]
    assert [e["label"] for e in spawned] == ["w1~r1"]
    assert spawned[0]["downtime_s"] >= policy.backoff_base
    # re-admission is real: the replacement serves a pinned stream
    post = RequestSpec(rid=10, prompt=np.arange(2, 12, dtype=np.int32),
                       max_tokens=6,
                       sampling=SamplingParams(temperature=0.7, top_k=8,
                                               seed=17))
    post_ref = _reference(cfg, params, [post])
    orch.instances[1].submit(post)
    orch._home[10] = 1
    out = {r.rid: r.generated for r in orch.run_until_done()}
    assert out[10] == post_ref[10]
    assert orch.dropped == 0


def test_flap_detector_evicts_a_crash_looping_worker(tiny):
    cfg, params = tiny
    local = LocalInstance(_mk_engine(cfg, params))
    worker = _respawnable(cfg, params)
    policy = RespawnPolicy(backoff_base=0.05, backoff_cap=0.1,
                           max_failures=2, window_s=10.0,
                           start_timeout=5.0)
    orch = Orchestrator(cfg, params, handles=[local, worker],
                        telemetry_every=10_000, respawn_policy=policy)
    worker.kill()
    _pump(orch, lambda: orch.faults.respawns == 1)
    # the replacement crashes too: second failure inside the window
    orch.instances[1].kill()
    _pump(orch, lambda: orch.faults.evictions == 1)
    assert 1 in orch._evicted
    assert [e["event"] for e in orch.respawn_log] == ["respawned",
                                                      "evicted"]
    # the breaker is permanent: no third bring-up, ever
    for _ in range(5):
        time.sleep(0.03)
        orch.step()
    assert orch.faults.respawns == 1
    assert orch.snapshot() is not None   # plane still reports


# ------------------------------------------------- hung-peer detection
class SilentRemote(InstanceHandle):
    """A half-open peer over REAL framed transport: the server thread
    reads every request and never replies, so the socket stays open and
    the only detection signal is the per-call deadline."""
    respawnable = False

    def __init__(self):
        self.telemetry = EngineTelemetry()
        self._conn, server_side = TR.socketpair()
        self._rpc = TR.Rpc(self._conn)
        self._mirror = []
        self._dead = False
        self.quarantined = False
        self._thread = threading.Thread(target=self._blackhole,
                                        args=(server_side,), daemon=True)
        self._thread.start()

    @staticmethod
    def _blackhole(conn):
        try:
            while True:
                conn.recv()
        except TR.TransportClosed:
            pass

    # ------------------------------------------------------- liveness
    def alive(self):
        return not self._dead

    def mark_dead(self):
        self._dead = True

    def set_rpc_deadline(self, seconds):
        self._rpc.call_timeout = seconds

    def probe(self, timeout=1.0):
        try:
            self._rpc.call_timed("heartbeat", timeout)
            return "alive"
        except TR.RpcTimeout:
            return "hung"
        except TR.TransportClosed:
            return "dead"

    def quarantine(self):
        self.quarantined = True
        self._dead = True
        self._conn.close()

    def close(self):
        self._dead = True
        self._conn.close()

    # ---------------------------------------------------- serving ops
    def submit(self, spec, trace=None):
        self._mirror.append(spec)            # mirror-first, then wire
        self._rpc.call_async("submit")       # vanishes into the hole

    def step_async(self):
        return self._rpc.call_async("step")

    def inflight_requests(self):
        return list(self._mirror)

    # --------------------------------------- gauges the router reads
    def queue_len(self):
        return len(self._mirror)

    def active_rids(self):
        return {}

    def free_blocks(self):
        return 1 << 30   # most vacant: the router MUST pick this peer

    def blocks_in_use(self):
        return 0

    def clock(self):
        return 0.0

    def preempt_count(self):
        return 0

    def prefix_stats(self):
        return {"queries": 0, "hits": 0, "blocks_saved_now": 0}


def test_hung_peer_is_classified_quarantined_and_replayed(tiny):
    """The tentpole's detection bound: a blackholed peer resolves to a
    ``hung`` poll entry within one deadline, the heartbeat probe spends
    at most one more confirming, and the stream it held finishes
    token-identically on the survivor."""
    cfg, params = tiny
    deadline = 0.25
    local = LocalInstance(_mk_engine(cfg, params))
    silent = SilentRemote()
    orch = Orchestrator(cfg, params, handles=[local, silent],
                        telemetry_every=10_000, rpc_deadline=deadline)
    req = RequestSpec(rid=0, prompt=np.arange(2, 12, dtype=np.int32),
                      max_tokens=6,
                      sampling=SamplingParams(temperature=0.7, top_k=8,
                                              seed=9))
    ref = _reference(cfg, params, [req])
    orch.submit(req)
    assert orch._home[0] == 1          # vacancy routing chose the peer
    done = {r.rid: r.generated for r in orch.run_until_done()}
    assert done == ref                 # replayed, token-identical
    assert silent.quarantined
    assert orch.faults.rpc_timeouts == 1
    assert orch.faults.quarantines == 1
    (rec,) = orch.recoveries
    assert rec["reason"] == "hung"
    assert rec["rids"] == [0]
    # drain expiry <= 1x deadline, probe <= 1x, plus scheduling slack
    assert rec["detect_s"] <= 2 * deadline + 0.3
    snap = orch.snapshot()
    assert snap.rpc_timeouts == 1 and snap.quarantines == 1


def test_probe_salvages_a_merely_slow_peer(tiny):
    """An ``alive`` probe verdict after a missed step deadline must NOT
    quarantine: in-order serving means the stale step reply (arrived
    while probing) is salvaged, or the step request frame was lost and
    skipping the tick is safe. Here the reply lands late."""
    cfg, params = tiny

    class SlowRemote(SilentRemote):
        @staticmethod
        def _blackhole(conn):
            # a real server, just slower than the deadline ONCE
            first = True
            try:
                while True:
                    msg = conn.recv()
                    if first:
                        time.sleep(0.35)
                        first = False
                    conn.send({"id": msg["id"], "ok": True,
                               "result": []})
            except TR.TransportClosed:
                pass

        def finish_step(self, reply):
            return reply

    local = LocalInstance(_mk_engine(cfg, params))
    slow = SlowRemote()
    orch = Orchestrator(cfg, params, handles=[local, slow],
                        telemetry_every=10_000, rpc_deadline=0.2)
    # nothing queued on the slow peer: one idle tick trips the deadline
    req = dataclasses.replace(_reqs(1)[0], rid=3)
    orch.instances[0].submit(req)
    orch._home[3] = 0
    orch.step()
    assert orch.faults.rpc_timeouts == 1
    assert orch.faults.quarantines == 0    # alive verdict: no sever
    assert slow.alive() and not slow.quarantined
    assert orch.recoveries == []
