"""SLO plumbing end-to-end: the ``RequestSpec`` fields (``slo_class``,
``deadline_ms``) must SURVIVE every path a request can take — engine
admission, preemption + replay, mid-prefill migration between
instances, and the versioned pause/resume wire payload — while the
token stream stays identical to the solo-engine oracle.

Also pins the migration payload versioning contract: an old- or
alien-shape payload is rejected with a clear ``ValueError`` naming the
version (a ``RemoteError`` over RPC), never a ``KeyError`` from deep
inside the bind path.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving.engine import Engine
from repro.serving.orchestrator import Orchestrator
from repro.serving.request import (MIGRATION_WIRE_VERSION, RequestSpec,
                                   SamplingParams, SpecError)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama-1.1b").reduced()
    return cfg, T.init_params(cfg, KEY, "float32")


def _reference(cfg, params, specs):
    out = {}
    for s in specs:
        e = Engine(cfg, params, max_batch=1, max_len=64,
                   cache_kind="paged", block_size=8)
        e.submit(s)
        out[s.rid] = e.run_until_done()[0].generated
    return out


# --------------------------------------------------- spec round trips
def test_spec_fields_round_trip_through_live_request():
    spec = RequestSpec(rid=7, prompt=np.arange(2, 10, dtype=np.int32),
                       max_tokens=5, slo_class="interactive",
                       deadline_ms=500.0,
                       sampling=SamplingParams(temperature=0.7, top_k=4,
                                               seed=3))
    req = spec.to_request()
    assert req.slo_class == "interactive" and req.deadline_ms == 500.0
    back = RequestSpec.from_request(req)
    assert (back.rid, back.max_tokens, back.slo_class,
            back.deadline_ms) == (7, 5, "interactive", 500.0)
    assert back.sampling == spec.sampling
    assert np.array_equal(back.prompt, spec.prompt)
    # a spec is already pristine: from_request passes it through
    assert RequestSpec.from_request(spec) is spec


def test_spec_validation_codes():
    base = dict(rid=0, prompt=np.arange(2, 6, dtype=np.int32))
    with pytest.raises(SpecError) as e:
        RequestSpec(slo_class="gold", **base).validate()
    assert e.value.code == "unknown_slo_class"
    with pytest.raises(SpecError) as e:
        RequestSpec(deadline_ms=0, **base).validate()
    assert e.value.code == "bad_deadline"
    with pytest.raises(SpecError) as e:
        RequestSpec(rid=0, prompt=np.zeros(0, dtype=np.int32)).validate()
    assert e.value.code == "malformed"


# ------------------------------------------- survival under preemption
def test_slo_class_survives_preemption_token_identically(tiny):
    """Pool pressure on an ``slo``-scheduled engine: preemption lands
    on BATCH streams only, the victims replay token-identically, and
    every finished request still carries its class and deadline."""
    cfg, params = tiny
    specs = [RequestSpec(rid=0, prompt=np.arange(2, 12, dtype=np.int32),
                         max_tokens=16, slo_class="interactive",
                         deadline_ms=1000.0,
                         sampling=SamplingParams(temperature=0.7,
                                                 top_k=8, seed=11))]
    specs += [RequestSpec(rid=i,
                          prompt=np.arange(3 + i, 13 + i, dtype=np.int32),
                          max_tokens=16, slo_class="batch",
                          sampling=SamplingParams(temperature=0.7,
                                                  top_k=8, seed=20 + i))
              for i in range(1, 4)]
    ref = _reference(cfg, params, specs)

    # 12 blocks for 4 streams needing ~4 each: guaranteed pressure
    e = Engine(cfg, params, max_batch=4, max_len=64, cache_kind="paged",
               block_size=8, n_blocks=12, prefix_sharing=False,
               scheduler="slo", token_budget=48)
    live = [e.submit(s) for s in specs]
    done = {r.rid: r for r in e.run_until_done()}
    assert {r.rid: r.generated for r in done.values()} == ref
    assert sum(r.preemptions for r in live) > 0, \
        "workload did not exercise preemption"
    # the victims policy never touched the interactive stream
    assert done[0].preemptions == 0
    for r in done.values():
        assert r.slo_class == ("interactive" if r.rid == 0 else "batch")
    assert done[0].deadline_ms == 1000.0


# ----------------------------------------- survival across migration
def test_slo_survives_mid_prefill_migration_token_identically(tiny):
    """A chunked prefill paused MID-PROMPT, migrated to a second
    instance, resumed there: the class/deadline arrive intact on the
    destination's live request and the stream is token-identical."""
    cfg, params = tiny
    spec = RequestSpec(rid=0, prompt=np.arange(2, 42, dtype=np.int32),
                       max_tokens=8, slo_class="interactive",
                       deadline_ms=750.0,
                       sampling=SamplingParams(temperature=0.6, top_k=8,
                                               seed=5))
    ref = _reference(cfg, params, [spec])

    orch = Orchestrator(cfg, params, n_instances=2, max_batch=2,
                        max_len=64, block_size=8, n_blocks=24,
                        telemetry_every=10_000, scheduler="slo",
                        token_budget=16)
    orch._home[spec.rid] = 0
    req = orch.engines[0].submit(spec)
    orch.step()
    assert req.slot in orch.engines[0].prefilling
    assert 0 < req.prefill_pos < len(spec.prompt)      # genuinely mid

    recs = orch.migrate_requests(0, 1)
    assert len(recs) == 1 and recs[0].resumed
    moved = next(r for r in
                 list(orch.engines[1].active.values())
                 + list(orch.engines[1].prefilling.values())
                 + list(orch.engines[1].queue) if r.rid == 0)
    assert moved.slo_class == "interactive"
    assert moved.deadline_ms == 750.0

    done = {r.rid: r.generated for r in orch.run_until_done()}
    assert done == ref
    assert orch.dropped == 0
    orch.close()


# --------------------------------------- versioned migration payloads
def _paused_payload(tiny):
    cfg, params = tiny
    e = Engine(cfg, params, max_batch=2, max_len=64, cache_kind="paged",
               block_size=8)
    req = e.submit(RequestSpec(rid=0,
                               prompt=np.arange(2, 12, dtype=np.int32),
                               max_tokens=8, slo_class="batch"))
    for _ in range(3):
        e.step()
    assert req.slot is not None
    return e, e.pause_request(req.slot)


def test_migration_payload_is_version_stamped(tiny):
    _, payload = _paused_payload(tiny)
    assert payload["v"] == MIGRATION_WIRE_VERSION
    assert payload["request"].slo_class == "batch"


@pytest.mark.parametrize("mutate", [
    lambda p: {k: v for k, v in p.items() if k != "v"},   # pre-version
    lambda p: dict(p, v=1),                               # old version
    lambda p: dict(p, v=MIGRATION_WIRE_VERSION + 1),      # future
])
def test_old_shape_payload_rejected_with_clear_error(tiny, mutate):
    e, payload = _paused_payload(tiny)
    bad = mutate(payload)
    with pytest.raises(ValueError, match="migration payload version"):
        e.resume_request(bad)
    with pytest.raises(ValueError, match="migration payload version"):
        e.prepare_resume(bad)
    # the rejection left the pool untouched: the GOOD payload still
    # resumes and decodes to completion
    assert e.resume_request(payload)
    (done,) = e.run_until_done()
    assert done.rid == 0 and len(done.generated) == 8


@pytest.mark.slow
def test_old_shape_payload_rejected_over_rpc(tiny):
    """The same rejection through a REAL spawned engine server: the
    ValueError crosses the wire as RemoteError carrying the version
    message — not a KeyError, not a dead worker."""
    cfg, params = tiny
    from repro.serving import transport as TR
    from repro.serving.remote_engine import EngineProxy
    _, payload = _paused_payload(tiny)
    px = EngineProxy(cfg, params, max_batch=2, max_len=64, block_size=8)
    try:
        with pytest.raises(TR.RemoteError,
                           match="migration payload version"):
            px.resume_request({k: v for k, v in payload.items()
                               if k != "v"})
        assert px.alive()                    # the worker survived it
        assert px.resume_request(payload)
        done = []
        for _ in range(40):
            done += px.step()
            if done:
                break
        assert done and done[0].rid == 0
    finally:
        px.close()
