"""Production-feature extensions: paged KV, chunked prefill, sampling,
heterogeneous scale-up, gradient accumulation, traffic traces — plus
hypothesis property tests on attention causality."""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.configs import get_config
from repro.core.cluster import Cluster, Device, GB
from repro.core.plan import PlacementPlan
from repro.core.scale_up import scale_up_hetero
from repro.core.speedup import SpeedupModelConfig, speedup
from repro.kernels.paged_decode import paged_decode_attention
from repro.models import transformer as T
from repro.serving import paged_kv as PK
from repro.serving.engine import Engine, Request
from repro.serving.workload import WorkloadConfig, generate_trace
from repro.training import optimizer as OPT
from repro.training import train as TR

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------- paged KV
def _filled_state(cfg, lens, block_size=8):
    state = PK.init_paged(cfg, max_batch=len(lens), n_blocks=64,
                          block_size=block_size, dtype="float32", max_len=256)
    rng = np.random.default_rng(0)
    L, KV, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    for slot, n in enumerate(lens):
        PK.allocate(state, slot, n)
        state = PK.write_tokens(
            state, slot,
            jnp.asarray(rng.normal(size=(L, n, KV, hd)), jnp.float32),
            jnp.asarray(rng.normal(size=(L, n, KV, hd)), jnp.float32))
    return state


def test_paged_kernel_matches_ref():
    cfg = get_config("tinyllama-1.1b").reduced()
    state = _filled_state(cfg, [20, 7, 33])
    q = jax.random.normal(KEY, (3, cfg.num_kv_heads,
                                cfg.resolved_head_dim), jnp.float32)
    ref = PK.paged_attention_ref(q, state, [0, 1, 2], layer=0)
    out = paged_decode_attention(
        q, state.k[0], state.v[0], jnp.asarray(state.block_tables),
        jnp.asarray(state.lengths[:3]), interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_paged_alloc_free_cycle():
    cfg = get_config("tinyllama-1.1b").reduced()
    state = _filled_state(cfg, [20, 7])
    used = state.blocks_in_use()
    assert used == -(-20 // 8) + -(-7 // 8)
    PK.free_slot(state, 0)
    assert state.blocks_in_use() == -(-7 // 8)
    assert 0.0 < state.utilization() <= 1.0
    with pytest.raises(PK.OutOfBlocks):
        PK.allocate(state, 0, 10_000)


def test_paged_gather_matches_written():
    cfg = get_config("tinyllama-1.1b").reduced()
    state = PK.init_paged(cfg, max_batch=1, n_blocks=16, block_size=8,
                          dtype="float32", max_len=64)
    L, KV, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    k_new = jax.random.normal(KEY, (L, 13, KV, hd), jnp.float32)
    PK.allocate(state, 0, 13)
    state = PK.write_tokens(state, 0, k_new, k_new * 2)
    k, v = PK.gather_request(state, 0, 13)
    np.testing.assert_allclose(np.asarray(k), np.asarray(k_new), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(v), np.asarray(k_new * 2), rtol=1e-6)


# ---------------------------------------------------- chunked prefill + sample
@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-780m"])
def test_chunked_prefill_equivalence(arch):
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, KEY, "float32")
    prompt = np.random.default_rng(3).integers(
        2, cfg.vocab_size, size=19).astype(np.int32)
    outs = []
    for chunk in (0, 7):
        e = Engine(cfg, params, max_batch=2, max_len=64, prefill_chunk=chunk)
        e.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
        outs.append(e.run_until_done()[0].generated)
    assert outs[0] == outs[1]


def test_sampling_seeded():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, KEY, "float32")
    prompt = np.arange(2, 10).astype(np.int32)
    gens = []
    for seed in (1, 1, 2):
        e = Engine(cfg, params, max_batch=1, max_len=64)
        e.submit(Request(rid=0, prompt=prompt, max_new_tokens=5,
                         temperature=0.8, top_k=16, seed=seed))
        gens.append(e.run_until_done()[0].generated)
    assert gens[0] == gens[1]
    assert gens[0] != gens[2]


# -------------------------------------------------------- hetero scale-up
def test_hetero_scale_up_prefers_fast_devices():
    # NOTE: under the exact Eq. 3 with honest units, a SINGLE replica never
    # pays at NVLink-1 bandwidth (the boundary comm exceeds one layer's
    # savings — the reason the paper's Alg. 1 sorts by continuity). A
    # 400 GB/s link (NVLink-4 class) makes the greedy first step viable.
    devices = [Device(0, compute_flops=312e12, mem_capacity=40 * GB),
               Device(1, compute_flops=312e12, mem_capacity=40 * GB),
               Device(2, compute_flops=78e12, mem_capacity=40 * GB)]
    cluster = Cluster(devices=devices, link_bandwidth=400 * GB)
    m = SpeedupModelConfig(d_model=5120, seq_len=256, batch_size=16)
    plan = scale_up_hetero(PlacementPlan.initial(16), cluster, model=m,
                           replica_size=605e6)
    assert speedup(plan, m, cluster) > 1.0
    fast = sum(reps.count(1) for reps in plan.replicas.values())
    slow = sum(reps.count(2) for reps in plan.replicas.values())
    assert fast >= slow  # Eq. 3 weights capacity; slow device helps less


def test_hetero_scale_up_slow_link_declines():
    """Exact-Eq.3 greedy correctly refuses replication when per-boundary
    communication exceeds per-layer compute savings (slow interconnect)."""
    cluster = Cluster.homogeneous(3, link_gbps=64)
    m = SpeedupModelConfig(d_model=5120, seq_len=256, batch_size=16)
    plan = scale_up_hetero(PlacementPlan.initial(16), cluster, model=m,
                           replica_size=605e6)
    assert plan.p == [1] * 16  # no replica pays for itself


# ------------------------------------------------------------- grad accum
def test_grad_accum_matches_full_batch():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, KEY, "float32")
    ocfg = OPT.OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10,
                               clip_norm=None)
    tokens = jax.random.randint(KEY, (8, 16), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens,
             "mask": jnp.ones((8, 16), jnp.float32)}
    # compare the accumulated GRADIENT against the full-batch gradient
    # (post-Adam params amplify fp noise through the rsqrt normalizer)
    loss_fn = TR.make_loss_fn(cfg)
    g_full = jax.grad(lambda p: loss_fn(p, batch)[0])(params)
    micros = jax.tree_util.tree_map(
        lambda x: x.reshape(4, 2, *x.shape[1:]), batch)
    g_acc = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p), params)
    for i in range(4):
        mb = jax.tree_util.tree_map(lambda x: x[i], micros)
        gi = jax.grad(lambda p: loss_fn(p, mb)[0])(params)
        g_acc = jax.tree_util.tree_map(lambda a, b: a + b / 4, g_acc, gi)
    for a, b in zip(jax.tree_util.tree_leaves(g_full),
                    jax.tree_util.tree_leaves(g_acc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)
    # and the jitted accumulating step runs + produces finite loss
    acc = TR.make_train_step_accum(cfg, ocfg, accum_steps=4)
    _, _, m2 = jax.jit(acc)(params, OPT.init_opt_state(params), batch)
    assert np.isfinite(float(m2["total_loss"]))


# ---------------------------------------------------------------- traces
@pytest.mark.parametrize("pattern", ["burst", "diurnal"])
def test_traffic_traces(pattern):
    wl = WorkloadConfig(rps=10, duration_s=30, seed=1)
    reqs = generate_trace(wl, pattern)
    assert len(reqs) > 100
    arr = np.array([r.arrival for r in reqs])
    mid = ((arr >= 10) & (arr < 20)).sum()
    edge = (arr < 10).sum()
    if pattern == "burst":
        assert mid > 2 * edge  # the spike is visible


# ----------------------------------------------------- causality property
@given(st.integers(0, 6), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=8, deadline=None)
def test_attention_causality(perturb_pos, seed):
    """Perturbing token t must not change logits at positions < t."""
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), "float32")
    rng = np.random.default_rng(seed)
    toks = rng.integers(2, cfg.vocab_size, size=(1, 8)).astype(np.int32)
    toks2 = toks.copy()
    toks2[0, perturb_pos] = (toks2[0, perturb_pos] + 7) % cfg.vocab_size
    a, _, _ = T.forward(params, cfg, jnp.asarray(toks), mode="train")
    b, _, _ = T.forward(params, cfg, jnp.asarray(toks2), mode="train")
    if perturb_pos > 0:
        np.testing.assert_allclose(np.asarray(a[0, :perturb_pos]),
                                   np.asarray(b[0, :perturb_pos]),
                                   rtol=1e-5, atol=1e-5)
    assert not np.allclose(np.asarray(a[0, perturb_pos]),
                           np.asarray(b[0, perturb_pos]))
