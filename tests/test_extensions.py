"""Production-feature extensions: paged KV, chunked prefill, sampling,
heterogeneous scale-up, gradient accumulation, traffic traces — plus
hypothesis property tests on attention causality."""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.configs import get_config
from repro.core.cluster import Cluster, Device, GB
from repro.core.plan import PlacementPlan
from repro.core.scale_up import scale_up_hetero
from repro.core.speedup import SpeedupModelConfig, speedup
from repro.kernels.paged_decode import paged_decode_attention
from repro.models import transformer as T
from repro.serving import paged_kv as PK
from repro.serving.engine import Engine
from repro.serving.request import RequestSpec, SamplingParams
from repro.serving.workload import WorkloadConfig, generate_trace
from repro.training import optimizer as OPT
from repro.training import train as TR

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------- paged KV
def _filled_state(cfg, lens, block_size=8):
    state = PK.init_paged(cfg, max_batch=len(lens), n_blocks=64,
                          block_size=block_size, dtype="float32", max_len=256)
    rng = np.random.default_rng(0)
    L, KV, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    for slot, n in enumerate(lens):
        PK.allocate(state, slot, n)
        state = PK.write_tokens(
            state, slot,
            jnp.asarray(rng.normal(size=(L, n, KV, hd)), jnp.float32),
            jnp.asarray(rng.normal(size=(L, n, KV, hd)), jnp.float32))
    return state


def test_paged_kernel_matches_ref():
    cfg = get_config("tinyllama-1.1b").reduced()
    state = _filled_state(cfg, [20, 7, 33])
    q = jax.random.normal(KEY, (3, cfg.num_kv_heads,
                                cfg.resolved_head_dim), jnp.float32)
    ref = PK.paged_attention_ref(q, state, [0, 1, 2], layer=0)
    out = paged_decode_attention(
        q, state.k[0], state.v[0], jnp.asarray(state.block_tables),
        jnp.asarray(state.lengths[:3]), interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_paged_alloc_free_cycle():
    cfg = get_config("tinyllama-1.1b").reduced()
    state = _filled_state(cfg, [20, 7])
    used = state.blocks_in_use()
    assert used == -(-20 // 8) + -(-7 // 8)
    PK.free_slot(state, 0)
    assert state.blocks_in_use() == -(-7 // 8)
    assert 0.0 < state.utilization() <= 1.0
    with pytest.raises(PK.OutOfBlocks):
        PK.allocate(state, 0, 10_000)


def test_paged_gather_matches_written():
    cfg = get_config("tinyllama-1.1b").reduced()
    state = PK.init_paged(cfg, max_batch=1, n_blocks=16, block_size=8,
                          dtype="float32", max_len=64)
    L, KV, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    k_new = jax.random.normal(KEY, (L, 13, KV, hd), jnp.float32)
    PK.allocate(state, 0, 13)
    state = PK.write_tokens(state, 0, k_new, k_new * 2)
    k, v = PK.gather_request(state, 0, 13)
    np.testing.assert_allclose(np.asarray(k), np.asarray(k_new), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(v), np.asarray(k_new * 2), rtol=1e-6)


def _raw_state(lens, *, n_blocks=16, bs=8, KV=2, hd=64, dtype=jnp.float32):
    """Pool built without a config — lets tests pick H != KV freely."""
    state = PK.PagedState(
        k=jnp.zeros((1, n_blocks, KV, bs, hd), dtype),   # KV-head-major
        v=jnp.zeros((1, n_blocks, KV, bs, hd), dtype),
        block_tables=np.full((len(lens), -(-max(lens) // bs) + 1), -1,
                             np.int32),
        lengths=np.zeros((len(lens),), np.int32),
        free=list(range(n_blocks)), block_size=bs)
    rng = np.random.default_rng(7)
    for slot, n in enumerate(lens):
        PK.allocate(state, slot, n)
        state = PK.write_tokens(
            state, slot,
            jnp.asarray(rng.normal(size=(1, n, KV, hd)), dtype),
            jnp.asarray(rng.normal(size=(1, n, KV, hd)), dtype))
    return state


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 3e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_paged_kernel_gqa_matches_ref(dtype, tol):
    """H > KV: query heads grouped by KV head inside the kernel."""
    H, KV, hd = 8, 2, 64
    state = _raw_state([20, 7, 33], KV=KV, hd=hd, dtype=dtype)
    q = jax.random.normal(KEY, (3, H, hd), jnp.float32)
    ref = PK.paged_attention_ref(q.astype(dtype), state, [0, 1, 2], layer=0)
    out = paged_decode_attention(
        q.astype(dtype), state.k[0], state.v[0],
        jnp.asarray(state.block_tables), jnp.asarray(state.lengths),
        interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_paged_kernel_ragged_block_boundaries():
    """Lengths at, just past, and far from block boundaries — plus an
    inactive (length 0) slot, which must yield exactly zeros."""
    bs = 8
    lens = [bs, 2 * bs, 1, 2 * bs + 1]
    state = _raw_state(lens, bs=bs, KV=4, hd=64)
    q = jax.random.normal(KEY, (4, 4, 64), jnp.float32)
    ref = PK.paged_attention_ref(q, state, [0, 1, 2, 3], layer=0)
    lengths = np.array(lens, np.int32)
    out = paged_decode_attention(
        q, state.k[0], state.v[0], jnp.asarray(state.block_tables),
        jnp.asarray(lengths), interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)
    lengths[1] = 0  # deactivate a slot
    out0 = paged_decode_attention(
        q, state.k[0], state.v[0], jnp.asarray(state.block_tables),
        jnp.asarray(lengths), interpret=True)
    assert (np.asarray(out0[1]) == 0).all()


def _run_engine(cfg, params, prompts, *, max_new=6, **kw):
    e = Engine(cfg, params, max_batch=2, max_len=64, **kw)
    for i, p in enumerate(prompts):
        e.submit(RequestSpec(rid=i, prompt=p, max_tokens=max_new))
    done = e.run_until_done()
    return {r.rid: r.generated for r in done}


def test_paged_engine_matches_dense_greedy():
    """Primary-path parity: the paged engine (batched same-length prefill,
    block-pool decode, on-device sampling) reproduces the dense engine's
    greedy outputs token for token — including ragged prompt lengths that
    cross block boundaries mid-generation."""
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, KEY, "float32")
    rng = np.random.default_rng(5)
    prompts = [rng.integers(2, cfg.vocab_size, size=s).astype(np.int32)
               for s in (8, 8, 5, 11)]  # two same-length -> batched prefill
    dense = _run_engine(cfg, params, prompts, cache_kind="dense")
    paged = _run_engine(cfg, params, prompts, cache_kind="paged",
                        block_size=8)
    assert paged == dense
    # the Pallas kernel path (interpret mode) agrees too
    kern = _run_engine(cfg, params, prompts[:2], max_new=3,
                       cache_kind="paged", block_size=8,
                       paged_attn_impl="kernel", interpret=True)
    assert kern == {k: v[:3] for k, v in dense.items() if k < 2}


def test_paged_engine_out_of_blocks_backpressure():
    """A pool too small for all requests at once: admission defers
    (requests wait in queue), decode pressure preempts — and every
    request still finishes with exactly the unconstrained outputs."""
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, KEY, "float32")
    rng = np.random.default_rng(6)
    prompts = [rng.integers(2, cfg.vocab_size, size=s).astype(np.int32)
               for s in (8, 8, 5, 11)]
    full = _run_engine(cfg, params, prompts, max_new=12,
                       cache_kind="paged", block_size=8)
    e = Engine(cfg, params, max_batch=2, max_len=64, cache_kind="paged",
               block_size=8, n_blocks=4)
    for i, p in enumerate(prompts):
        e.submit(RequestSpec(rid=i, prompt=p, max_tokens=12))
    waited = False
    done = []
    for _ in range(400):
        if e.queue and e.active:
            waited = True
        done += e.step() or []
        if not e.queue and not e.active:
            break
    assert waited, "pool was never under pressure"
    assert len(done) == len(prompts)
    assert {r.rid: r.generated for r in done} == full
    # a request larger than the whole pool is a hard error, not a hang —
    # and it must not take the rest of the admission wave down with it
    e2 = Engine(cfg, params, max_batch=2, max_len=64, cache_kind="paged",
                block_size=8, n_blocks=2)
    e2.submit(RequestSpec(rid=0, prompt=np.arange(2, 40, dtype=np.int32),
                      max_tokens=4))
    e2.submit(RequestSpec(rid=1, prompt=np.arange(2, 8, dtype=np.int32),
                      max_tokens=2))
    with pytest.raises(PK.OutOfBlocks):
        e2.run_until_done()
    done2 = e2.run_until_done()  # wave-mate survived the rejection
    assert [r.rid for r in done2] == [1]
    assert e2.pstate.blocks_in_use() == 0  # nothing leaked
    # a lone request whose GENERATION outgrows the pool is evicted with
    # truncated output (loud, but the engine stays serviceable)
    e3 = Engine(cfg, params, max_batch=1, max_len=64, cache_kind="paged",
                block_size=8, n_blocks=2)
    big = e3.submit(RequestSpec(rid=0,
                                prompt=np.arange(2, 10, dtype=np.int32),
                                max_tokens=30))
    with pytest.raises(PK.OutOfBlocks):
        e3.run_until_done()
    assert big.done and 0 < len(big.generated) < 30
    assert not e3.active and e3.pstate.blocks_in_use() == 0
    e3.submit(RequestSpec(rid=1, prompt=np.arange(2, 8, dtype=np.int32),
                      max_tokens=2))
    assert [r.rid for r in e3.run_until_done()] == [1]  # still serviceable
    # prompt == max_len would overflow the block-table row: clean
    # rejection (no IndexError, no leaked block, engine still serviceable)
    e4 = Engine(cfg, params, max_batch=2, max_len=32, cache_kind="paged",
                block_size=8)
    e4.submit(RequestSpec(rid=0, prompt=np.full(32, 3, np.int32),
                      max_tokens=4))
    e4.submit(RequestSpec(rid=1, prompt=np.full(31, 3, np.int32),  # just fits
                      max_tokens=4))
    with pytest.raises(PK.OutOfBlocks):
        e4.run_until_done()
    done4 = e4.run_until_done()
    assert [r.rid for r in done4] == [1]
    assert e4.pstate.blocks_in_use() == 0


# ---------------------------------------------------- chunked prefill + sample
@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-780m"])
def test_chunked_prefill_equivalence(arch):
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, KEY, "float32")
    prompt = np.random.default_rng(3).integers(
        2, cfg.vocab_size, size=19).astype(np.int32)
    outs = []
    for chunk in (0, 7):
        e = Engine(cfg, params, max_batch=2, max_len=64, prefill_chunk=chunk)
        e.submit(RequestSpec(rid=0, prompt=prompt, max_tokens=4))
        outs.append(e.run_until_done()[0].generated)
    assert outs[0] == outs[1]


def test_sampling_seeded():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, KEY, "float32")
    prompt = np.arange(2, 10).astype(np.int32)
    gens = []
    for seed in (1, 1, 2):
        e = Engine(cfg, params, max_batch=1, max_len=64)
        e.submit(RequestSpec(rid=0, prompt=prompt, max_tokens=5,
                             sampling=SamplingParams(temperature=0.8,
                                                     top_k=16,
                                                     seed=seed)))
        gens.append(e.run_until_done()[0].generated)
    assert gens[0] == gens[1]
    assert gens[0] != gens[2]


# -------------------------------------------------------- hetero scale-up
def test_hetero_scale_up_prefers_fast_devices():
    # NOTE: under the exact Eq. 3 with honest units, a SINGLE replica never
    # pays at NVLink-1 bandwidth (the boundary comm exceeds one layer's
    # savings — the reason the paper's Alg. 1 sorts by continuity). A
    # 400 GB/s link (NVLink-4 class) makes the greedy first step viable.
    devices = [Device(0, compute_flops=312e12, mem_capacity=40 * GB),
               Device(1, compute_flops=312e12, mem_capacity=40 * GB),
               Device(2, compute_flops=78e12, mem_capacity=40 * GB)]
    cluster = Cluster(devices=devices, link_bandwidth=400 * GB)
    m = SpeedupModelConfig(d_model=5120, seq_len=256, batch_size=16)
    plan = scale_up_hetero(PlacementPlan.initial(16), cluster, model=m,
                           replica_size=605e6)
    assert speedup(plan, m, cluster) > 1.0
    fast = sum(reps.count(1) for reps in plan.replicas.values())
    slow = sum(reps.count(2) for reps in plan.replicas.values())
    assert fast >= slow  # Eq. 3 weights capacity; slow device helps less


def test_hetero_scale_up_slow_link_declines():
    """Exact-Eq.3 greedy correctly refuses replication when per-boundary
    communication exceeds per-layer compute savings (slow interconnect)."""
    cluster = Cluster.homogeneous(3, link_gbps=64)
    m = SpeedupModelConfig(d_model=5120, seq_len=256, batch_size=16)
    plan = scale_up_hetero(PlacementPlan.initial(16), cluster, model=m,
                           replica_size=605e6)
    assert plan.p == [1] * 16  # no replica pays for itself


# ------------------------------------------------------------- grad accum
def test_grad_accum_matches_full_batch():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, KEY, "float32")
    ocfg = OPT.OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10,
                               clip_norm=None)
    tokens = jax.random.randint(KEY, (8, 16), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens,
             "mask": jnp.ones((8, 16), jnp.float32)}
    # compare the accumulated GRADIENT against the full-batch gradient
    # (post-Adam params amplify fp noise through the rsqrt normalizer)
    loss_fn = TR.make_loss_fn(cfg)
    g_full = jax.grad(lambda p: loss_fn(p, batch)[0])(params)
    micros = jax.tree_util.tree_map(
        lambda x: x.reshape(4, 2, *x.shape[1:]), batch)
    g_acc = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p), params)
    for i in range(4):
        mb = jax.tree_util.tree_map(lambda x: x[i], micros)
        gi = jax.grad(lambda p: loss_fn(p, mb)[0])(params)
        g_acc = jax.tree_util.tree_map(lambda a, b: a + b / 4, g_acc, gi)
    for a, b in zip(jax.tree_util.tree_leaves(g_full),
                    jax.tree_util.tree_leaves(g_acc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)
    # and the jitted accumulating step runs + produces finite loss
    acc = TR.make_train_step_accum(cfg, ocfg, accum_steps=4)
    _, _, m2 = jax.jit(acc)(params, OPT.init_opt_state(params), batch)
    assert np.isfinite(float(m2["total_loss"]))


# ---------------------------------------------------------------- traces
@pytest.mark.parametrize("pattern", ["burst", "diurnal"])
def test_traffic_traces(pattern):
    wl = WorkloadConfig(rps=10, duration_s=30, seed=1)
    reqs = generate_trace(wl, pattern)
    assert len(reqs) > 100
    arr = np.array([r.arrival for r in reqs])
    mid = ((arr >= 10) & (arr < 20)).sum()
    edge = (arr < 10).sum()
    if pattern == "burst":
        assert mid > 2 * edge  # the spike is visible


# ----------------------------------------------------- causality property
@given(st.integers(0, 6), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=8, deadline=None)
def test_attention_causality(perturb_pos, seed):
    """Perturbing token t must not change logits at positions < t."""
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), "float32")
    rng = np.random.default_rng(seed)
    toks = rng.integers(2, cfg.vocab_size, size=(1, 8)).astype(np.int32)
    toks2 = toks.copy()
    toks2[0, perturb_pos] = (toks2[0, perturb_pos] + 7) % cfg.vocab_size
    a, _, _ = T.forward(params, cfg, jnp.asarray(toks), mode="train")
    b, _, _ = T.forward(params, cfg, jnp.asarray(toks2), mode="train")
    if perturb_pos > 0:
        np.testing.assert_allclose(np.asarray(a[0, :perturb_pos]),
                                   np.asarray(b[0, :perturb_pos]),
                                   rtol=1e-5, atol=1e-5)
    assert not np.allclose(np.asarray(a[0, perturb_pos]),
                           np.asarray(b[0, perturb_pos]))
