"""Config registry: geometry, param counts, reduced variants."""
import pytest

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config, list_archs

# nominal sizes (billions) with loose tolerance; geometry is from the
# assignment so "name" sizes are only approximate for some entries
NOMINAL_B = {
    "minicpm3-4b": (3.4, 4.9),
    "whisper-medium": (0.6, 1.0),
    "zamba2-7b": (5.5, 8.0),          # assigned 81L geometry
    "tinyllama-1.1b": (0.95, 1.25),
    "chameleon-34b": (29, 38),
    "arctic-480b": (430, 520),
    "qwen2-moe-a2.7b": (12, 16),      # total params (A2.7 = active)
    "stablelm-12b": (10.5, 13.5),
    "mamba2-780m": (0.68, 0.88),
    "gemma-7b": (7.5, 9.5),
    "llama2-13b": (11.5, 14.5),
    "llama2-70b": (62, 76),
}


def test_registry_complete():
    assert len(ASSIGNED_ARCHS) == 10
    assert len(list_archs()) == 12  # + the paper's two llama models
    assert len(INPUT_SHAPES) == 4


@pytest.mark.parametrize("arch", list_archs())
def test_param_counts(arch):
    cfg = get_config(arch)
    lo, hi = NOMINAL_B[arch]
    count = cfg.param_count() / 1e9
    assert lo <= count <= hi, f"{arch}: {count:.2f}B not in [{lo},{hi}]"


@pytest.mark.parametrize("arch", list_archs())
def test_reduced_is_small(arch):
    r = get_config(arch).reduced()
    assert r.num_layers == 2
    assert r.d_model <= 512
    assert r.padded_experts() <= 16 and (r.num_experts in (0, 4))
    assert r.param_count() < 30e6


@pytest.mark.parametrize("arch", list_archs())
def test_padded_vocab_divisible(arch):
    cfg = get_config(arch)
    assert cfg.padded_vocab % 256 == 0
    assert cfg.padded_vocab >= cfg.vocab_size


def test_input_shapes_exact():
    s = INPUT_SHAPES
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq_len, s["prefill_32k"].global_batch) == (32768, 32)
    assert (s["decode_32k"].seq_len, s["decode_32k"].global_batch) == (32768, 128)
    assert (s["long_500k"].seq_len, s["long_500k"].global_batch) == (524288, 1)


def test_long_decode_support_flags():
    assert not get_config("whisper-medium").supports_long_decode
    assert get_config("mamba2-780m").supports_long_decode
    assert get_config("zamba2-7b").supports_long_decode
    assert get_config("gemma-7b").supports_long_decode  # via sliding window
