"""Distributed serving plane (tier-2: spawns real engine-server
processes; run with ``pytest -m slow``).

The ISSUE-4 acceptance scenario: a 2-worker MULTI-PROCESS deployment
(spawned processes, RPC frames over AF_UNIX sockets, no shared memory)
completes a burst with live scale-up and an overlapped scale-down
migration that is zero-drop and token-identical — plus crash recovery:
a remote instance killed mid-migration has its streams re-queued on a
surviving instance with zero drops, asserted token-identical via
counter-based replay.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving.engine import Engine, Request
from repro.serving.instance import LocalInstance
from repro.serving.orchestrator import Orchestrator

pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, KEY, "float32")
    return cfg, params


def _clone(r: Request) -> Request:
    return dataclasses.replace(r, generated=[], slot=None, submit_time=0.0,
                               first_token_time=None, finish_time=None,
                               preemptions=0)


def _reference_outputs(cfg, params, requests):
    out = {}
    for r in requests:
        e = Engine(cfg, params, max_batch=1, max_len=64,
                   cache_kind="paged", block_size=8)
        e.submit(_clone(r))
        out[r.rid] = e.run_until_done()[0].generated
    return out


def test_two_worker_burst_scale_up_and_overlapped_scale_down(tiny):
    """2 spawned engine-server processes behind RPC: a burst triggers
    live scale-up (replication degrees over the wire), then a drain
    executes an overlapped scale-down migration — zero drops and
    token-identical outputs for every migrated (and unmigrated) stream,
    with each worker's telemetry arriving as serialized snapshots."""
    cfg, params = tiny
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i,
                    prompt=rng.integers(2, cfg.vocab_size,
                                        size=8 + i % 4).astype(np.int32),
                    max_new_tokens=8, temperature=0.7 if i % 2 else 0.0,
                    top_k=8 if i % 2 else 0, seed=11 + i)
            for i in range(8)]
    ref = _reference_outputs(cfg, params, reqs)

    orch = Orchestrator(cfg, params, n_instances=2, max_batch=2,
                        max_len=64, block_size=8, n_blocks=32,
                        slo_latency=30.0, telemetry_every=2, remote=True)
    try:
        assert not orch.engines     # no local engine anywhere: all-RPC
        for r in reqs[:6]:          # the burst wave
            orch.submit(_clone(r))
        for _ in range(12):
            orch.step()
        # scale-up happened and reached the REMOTE engines (the degree
        # list rode an RPC frame; the next steps ran under the plan)
        assert any(a.startswith("scale-up") for a in orch.controller.log)
        assert sum(orch.plan.p) > cfg.num_layers

        for r in reqs[6:]:          # tail traffic, then consolidate
            orch.submit(_clone(r))
        for _ in range(3):
            orch.step()
        src = max((0, 1),
                  key=lambda i: orch.instances[i].active_count())
        if orch.instances[src].active_rids():
            recs = orch.drain_instance(src)
            assert recs, "drain moved no requests"
            assert all(r.mode == "overlapped" for r in recs)
            assert not orch.instances[src].active_rids()
        orch.run_until_done()

        all_done = {r.rid: r.generated for r in orch.finished}
        assert set(all_done) == {r.rid for r in reqs}
        for rid, gen in all_done.items():
            assert gen == ref[rid], f"rid {rid} diverged"
        assert orch.dropped == 0
        # telemetry mirrors were fed from the servers' serialized state
        assert all(t.total_tokens > 0 for t in orch.telemetry)
    finally:
        orch.close()


def test_remote_crash_mid_migration_replays_on_survivor(tiny):
    """A REMOTE instance killed mid-migration (phase 1 staged, phase 2
    never arrives): its streams — active mid-decode and queued — are
    re-queued on the surviving instance and replayed via counter-based
    sampling, token-identical, with zero drops. Mixed topology: the
    survivor is a local in-process engine, proving local and remote
    compose behind one InstanceHandle interface."""
    cfg, params = tiny
    reqs = [Request(rid=i, prompt=np.arange(2 + i, 14 + i, dtype=np.int32),
                    max_new_tokens=10, temperature=0.8, top_k=16,
                    seed=7 + i) for i in range(3)]
    ref = _reference_outputs(cfg, params, reqs)

    from repro.serving.remote_engine import EngineProxy
    local = LocalInstance(Engine(cfg, params, max_batch=3, max_len=64,
                                 cache_kind="paged", block_size=8,
                                 n_blocks=32))
    remote = EngineProxy(cfg, params, max_batch=3, max_len=64,
                         block_size=8, n_blocks=32)
    orch = Orchestrator(cfg, params, handles=[local, remote],
                        telemetry_every=10_000)
    try:
        # two active + one queued-ish on the REMOTE instance
        for r in reqs:
            orch._home[r.rid] = 1
            orch.instances[1].submit(_clone(r))
        for _ in range(3):
            orch.step()
        assert orch.instances[1].active_rids()
        victim_slot = sorted(orch.instances[1].active_rids())[0]

        ticket = orch.begin_migration(1, 0, victim_slot)
        orch.instances[1].kill()            # dies with phase 1 staged
        rec = orch.finish_migration(ticket)
        assert rec is None
        assert len(orch.recoveries) == 1
        assert sorted(orch.recoveries[0]["rids"]) == [0, 1, 2]
        # the local survivor's staged phase-1 blocks were freed
        assert not local.engine._staged

        orch.run_until_done()
        all_done = {r.rid: r.generated for r in orch.finished}
        assert set(all_done) == {0, 1, 2}
        for rid, gen in all_done.items():
            assert gen == ref[rid], f"rid {rid} diverged after replay"
        assert orch.dropped == 0
        assert local.engine.pstate.blocks_in_use() == 0
    finally:
        orch.close()


def test_destination_death_after_pause_replays_at_source(tiny):
    """The nastiest migration failure: the destination dies AFTER the
    source has already detached the stream (pause done, commit never
    lands) — the payload in hand is the stream's only copy. The finish
    path must hand it back to the (alive) source for deterministic
    replay: zero drops, token-identical, and recovery fires exactly
    once despite the death being observable from several operations."""
    cfg, params = tiny
    reqs = [Request(rid=i, prompt=np.arange(2 + i, 14 + i, dtype=np.int32),
                    max_new_tokens=10, temperature=0.8, top_k=16,
                    seed=7 + i) for i in range(2)]
    ref = _reference_outputs(cfg, params, reqs)

    from repro.serving.remote_engine import EngineProxy
    local = LocalInstance(Engine(cfg, params, max_batch=2, max_len=64,
                                 cache_kind="paged", block_size=8,
                                 n_blocks=32))
    remote = EngineProxy(cfg, params, max_batch=2, max_len=64,
                         block_size=8, n_blocks=32)
    orch = Orchestrator(cfg, params, handles=[local, remote],
                        telemetry_every=10_000)
    try:
        for r in reqs:
            orch._home[r.rid] = 0
            orch.instances[0].submit(_clone(r))
        for _ in range(3):
            orch.step()
        victim_slot = sorted(orch.instances[0].active_rids())[0]
        ticket = orch.begin_migration(0, 1, victim_slot)

        real_commit = remote.commit_resume

        def dying_commit(slot, payload):
            remote.kill()               # dies with the delta in flight
            return real_commit(slot, payload)

        remote.commit_resume = dying_commit
        rec = orch.finish_migration(ticket)
        assert rec is None
        # the paused stream went BACK to the source's queue for replay
        assert len(local.engine.queue) == 1
        assert len(orch.recoveries) == 1
        # a second observation of the same death must not replay again
        assert orch.handle_instance_failure(1) == []
        assert len(orch.recoveries) == 1

        orch.run_until_done()
        all_done = {r.rid: r.generated for r in orch.finished}
        assert set(all_done) == {0, 1}
        for rid, gen in all_done.items():
            assert gen == ref[rid], f"rid {rid} diverged"
        assert orch.dropped == 0
        assert local.engine.pstate.blocks_in_use() == 0
    finally:
        orch.close()


def test_remote_streams_match_local_streams(tiny):
    """The same workload through a remote proxy and a local engine
    produces byte-identical token streams — the wire protocol carries
    admissions/sampling state losslessly."""
    cfg, params = tiny
    reqs = [Request(rid=i, prompt=np.arange(3 + i, 13 + i, dtype=np.int32),
                    max_new_tokens=6, temperature=0.9, top_k=12,
                    seed=21 + i) for i in range(3)]
    ref = _reference_outputs(cfg, params, reqs)
    from repro.serving.remote_engine import EngineProxy
    px = EngineProxy(cfg, params, max_batch=3, max_len=64, block_size=8)
    try:
        for r in reqs:
            px.submit(_clone(r))
        done = []
        for _ in range(40):
            done += px.step()
            if not px.active_rids() and px.queue_len() == 0:
                break
        assert {r.rid: r.generated for r in done} == ref
    finally:
        px.close()
