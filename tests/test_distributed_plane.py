"""Distributed serving plane (tier-2: spawns real engine-server
processes; run with ``pytest -m slow``).

The ISSUE-4 acceptance scenario: a 2-worker MULTI-PROCESS deployment
(spawned processes, RPC frames over stream sockets, no shared memory)
completes a burst with live scale-up and an overlapped scale-down
migration that is zero-drop and token-identical — plus crash recovery:
a remote instance killed mid-migration has its streams re-queued on a
surviving instance with zero drops, asserted token-identical via
counter-based replay.

ISSUE-5 lifts the same suite multi-host: the whole module runs
unchanged over loopback TCP endpoints under ``REPRO_RPC_TRANSPORT=tcp``
(the nightly CI job does exactly that), and the TCP-pod test below
drives a launch/pod.py inventory deployment through the batched
control-plane poll, killing a worker mid-tick so the death surfaces
inside the multiplexed drain rather than from a direct call.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving.engine import Engine
from repro.serving.request import RequestSpec, SamplingParams
from repro.serving.instance import LocalInstance
from repro.serving.orchestrator import Orchestrator

pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, KEY, "float32")
    return cfg, params


def _reference_outputs(cfg, params, requests):
    out = {}
    for r in requests:
        e = Engine(cfg, params, max_batch=1, max_len=64,
                   cache_kind="paged", block_size=8)
        e.submit(r)
        out[r.rid] = e.run_until_done()[0].generated
    return out


def test_two_worker_burst_scale_up_and_overlapped_scale_down(tiny):
    """2 spawned engine-server processes behind RPC: a burst triggers
    live scale-up (replication degrees over the wire), then a drain
    executes an overlapped scale-down migration — zero drops and
    token-identical outputs for every migrated (and unmigrated) stream,
    with each worker's telemetry arriving as serialized snapshots."""
    cfg, params = tiny
    rng = np.random.default_rng(3)
    reqs = [RequestSpec(rid=i,
                        prompt=rng.integers(2, cfg.vocab_size,
                                            size=8 + i % 4).astype(np.int32),
                        max_tokens=8,
                        sampling=SamplingParams(
                            temperature=0.7 if i % 2 else 0.0,
                            top_k=8 if i % 2 else 0, seed=11 + i))
            for i in range(8)]
    ref = _reference_outputs(cfg, params, reqs)

    orch = Orchestrator(cfg, params, n_instances=2, max_batch=2,
                        max_len=64, block_size=8, n_blocks=32,
                        slo_latency=30.0, telemetry_every=2, remote=True)
    try:
        assert not orch.engines     # no local engine anywhere: all-RPC
        for r in reqs[:6]:          # the burst wave
            orch.submit(r)
        for _ in range(12):
            orch.step()
        # scale-up happened and reached the REMOTE engines (the degree
        # list rode an RPC frame; the next steps ran under the plan)
        assert any(a.startswith("scale-up") for a in orch.controller.log)
        assert sum(orch.plan.p) > cfg.num_layers

        for r in reqs[6:]:          # tail traffic, then consolidate
            orch.submit(r)
        for _ in range(3):
            orch.step()
        src = max((0, 1),
                  key=lambda i: orch.instances[i].active_count())
        if orch.instances[src].active_rids():
            recs = orch.drain_instance(src)
            assert recs, "drain moved no requests"
            assert all(r.mode == "overlapped" for r in recs)
            assert not orch.instances[src].active_rids()
        orch.run_until_done()

        all_done = {r.rid: r.generated for r in orch.finished}
        assert set(all_done) == {r.rid for r in reqs}
        for rid, gen in all_done.items():
            assert gen == ref[rid], f"rid {rid} diverged"
        assert orch.dropped == 0
        # telemetry mirrors were fed from the servers' serialized state
        assert all(t.total_tokens > 0 for t in orch.telemetry)
    finally:
        orch.close()


def test_remote_crash_mid_migration_replays_on_survivor(tiny):
    """A REMOTE instance killed mid-migration (phase 1 staged, phase 2
    never arrives): its streams — active mid-decode and queued — are
    re-queued on the surviving instance and replayed via counter-based
    sampling, token-identical, with zero drops. Mixed topology: the
    survivor is a local in-process engine, proving local and remote
    compose behind one InstanceHandle interface."""
    cfg, params = tiny
    reqs = [RequestSpec(rid=i,
                            prompt=np.arange(2 + i, 14 + i, dtype=np.int32),
                            max_tokens=10,
                            sampling=SamplingParams(temperature=0.8, top_k=16,
                                                    seed=7 + i))
                    for i in range(3)]
    ref = _reference_outputs(cfg, params, reqs)

    from repro.serving.remote_engine import EngineProxy
    local = LocalInstance(Engine(cfg, params, max_batch=3, max_len=64,
                                 cache_kind="paged", block_size=8,
                                 n_blocks=32))
    remote = EngineProxy(cfg, params, max_batch=3, max_len=64,
                         block_size=8, n_blocks=32)
    orch = Orchestrator(cfg, params, handles=[local, remote],
                        telemetry_every=10_000)
    try:
        # two active + one queued-ish on the REMOTE instance
        for r in reqs:
            orch._home[r.rid] = 1
            orch.instances[1].submit(r)
        for _ in range(3):
            orch.step()
        assert orch.instances[1].active_rids()
        victim_slot = sorted(orch.instances[1].active_rids())[0]

        ticket = orch.begin_migration(1, 0, victim_slot)
        orch.instances[1].kill()            # dies with phase 1 staged
        rec = orch.finish_migration(ticket)
        assert rec is None
        assert len(orch.recoveries) == 1
        assert sorted(orch.recoveries[0]["rids"]) == [0, 1, 2]
        # the local survivor's staged phase-1 blocks were freed
        assert not local.engine._staged

        orch.run_until_done()
        all_done = {r.rid: r.generated for r in orch.finished}
        assert set(all_done) == {0, 1, 2}
        for rid, gen in all_done.items():
            assert gen == ref[rid], f"rid {rid} diverged after replay"
        assert orch.dropped == 0
        assert local.engine.pstate.blocks_in_use() == 0
    finally:
        orch.close()


def test_destination_death_after_pause_replays_at_source(tiny):
    """The nastiest migration failure: the destination dies AFTER the
    source has already detached the stream (pause done, commit never
    lands) — the payload in hand is the stream's only copy. The finish
    path must hand it back to the (alive) source for deterministic
    replay: zero drops, token-identical, and recovery fires exactly
    once despite the death being observable from several operations."""
    cfg, params = tiny
    reqs = [RequestSpec(rid=i,
                            prompt=np.arange(2 + i, 14 + i, dtype=np.int32),
                            max_tokens=10,
                            sampling=SamplingParams(temperature=0.8, top_k=16,
                                                    seed=7 + i))
                    for i in range(2)]
    ref = _reference_outputs(cfg, params, reqs)

    from repro.serving.remote_engine import EngineProxy
    local = LocalInstance(Engine(cfg, params, max_batch=2, max_len=64,
                                 cache_kind="paged", block_size=8,
                                 n_blocks=32))
    remote = EngineProxy(cfg, params, max_batch=2, max_len=64,
                         block_size=8, n_blocks=32)
    orch = Orchestrator(cfg, params, handles=[local, remote],
                        telemetry_every=10_000)
    try:
        for r in reqs:
            orch._home[r.rid] = 0
            orch.instances[0].submit(r)
        for _ in range(3):
            orch.step()
        victim_slot = sorted(orch.instances[0].active_rids())[0]
        ticket = orch.begin_migration(0, 1, victim_slot)

        real_commit = remote.commit_resume

        def dying_commit(slot, payload):
            remote.kill()               # dies with the delta in flight
            return real_commit(slot, payload)

        remote.commit_resume = dying_commit
        rec = orch.finish_migration(ticket)
        assert rec is None
        # the paused stream went BACK to the source's queue for replay
        assert len(local.engine.queue) == 1
        assert len(orch.recoveries) == 1
        # a second observation of the same death must not replay again
        assert orch.handle_instance_failure(1) == []
        assert len(orch.recoveries) == 1

        orch.run_until_done()
        all_done = {r.rid: r.generated for r in orch.finished}
        assert set(all_done) == {0, 1}
        for rid, gen in all_done.items():
            assert gen == ref[rid], f"rid {rid} diverged"
        assert orch.dropped == 0
        assert local.engine.pstate.blocks_in_use() == 0
    finally:
        orch.close()


def test_tcp_pod_kill_mid_tick_replays_through_batched_poll(tiny, tmp_path):
    """ISSUE-5 acceptance: a TCP pod from a node inventory (spawned
    listening engine servers, orchestrator dials in with retry) serves
    through the batched control-plane poll — exactly one multiplexed
    drain per tick — and a worker killed MID-TICK (its death surfaces
    as a ``closed`` entry inside the drain, or as a silent death at the
    next fan-out; both fold into the same path) has every stream
    replayed token-identically on the survivor, exactly once."""
    cfg, params = tiny
    from repro.launch.pod import launch_pod, load_inventory
    from repro.serving import transport as TR

    ports = sorted(int(TR.free_tcp_endpoint().rsplit(":", 1)[1])
                   for _ in range(2))
    inv = tmp_path / "pod.toml"
    inv.write_text("".join(
        f'[[node]]\nhost = "127.0.0.1"\nport = {p}\n\n' for p in ports))
    handles = launch_pod(cfg, params, load_inventory(str(inv)),
                         max_batch=3, max_len=64, block_size=8,
                         n_blocks=32)
    assert [h.endpoint for h in handles] == \
        [f"tcp://127.0.0.1:{p}" for p in ports]

    reqs = [RequestSpec(rid=i,
                            prompt=np.arange(2 + i, 14 + i, dtype=np.int32),
                            max_tokens=10,
                            sampling=SamplingParams(temperature=0.8, top_k=16,
                                                    seed=7 + i))
                    for i in range(4)]
    ref = _reference_outputs(cfg, params, reqs)

    orch = Orchestrator(cfg, params, handles=handles,
                        telemetry_every=10_000)
    try:
        assert not orch.engines         # all-RPC, nothing in-process
        for r in reqs[:3]:              # load the victim worker
            orch._home[r.rid] = 0
            orch.instances[0].submit(r)
        orch._home[reqs[3].rid] = 1
        orch.instances[1].submit(reqs[3])
        for _ in range(3):
            orch.step()
        assert orch.instances[0].active_rids()

        # kill worker 0 mid-tick: the crash op makes the server os._exit
        # while this tick's step request is already on the wire, so the
        # drain — not a direct call — observes the EOF
        orch.instances[0].rpc.call_async("crash")
        orch.step()
        assert len(orch.recoveries) == 1
        assert sorted(orch.recoveries[0]["rids"]) == [0, 1, 2]
        # idempotent: a second observation of the same death is a no-op
        assert orch.handle_instance_failure(0) == []
        assert len(orch.recoveries) == 1

        orch.run_until_done()
        all_done = {r.rid: r.generated for r in orch.finished}
        assert set(all_done) == {0, 1, 2, 3}
        for rid, gen in all_done.items():
            assert gen == ref[rid], f"rid {rid} diverged after replay"
        assert orch.dropped == 0
        # the control plane issued ONE multiplexed poll per tick, never
        # N sequential waits
        cp = orch.control_plane_stats()
        assert cp["rpc_polls_per_tick"] == 1.0
        assert cp["step_rpcs_per_tick"] >= 1.0
    finally:
        orch.close()


def test_spawn_listen_fails_fast_when_port_is_taken(tiny):
    """A spawned listening engine server whose bind fails (port already
    occupied by a bound socket) exits immediately — the proxy's
    connect-retry must notice the child's death and abort with a clear
    error instead of retrying out the whole start_timeout."""
    import socket
    import time

    cfg, params = tiny
    from repro.serving import transport as TR
    from repro.serving.remote_engine import EngineProxy

    squatter = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    try:
        squatter.bind(("127.0.0.1", 0))     # bound, never listening:
        port = squatter.getsockname()[1]    # child gets EADDRINUSE,
        endpoint = f"tcp://127.0.0.1:{port}"  # parent gets refused
        t0 = time.perf_counter()
        with pytest.raises(TR.TransportError, match="exited"):
            EngineProxy(cfg, params, endpoint=endpoint, spawn=True,
                        start_timeout=60.0, max_batch=2, max_len=64,
                        block_size=8)
        assert time.perf_counter() - t0 < 30.0, \
            "child death was not detected; connect retried to deadline"
    finally:
        squatter.close()


def test_remote_streams_match_local_streams(tiny):
    """The same workload through a remote proxy and a local engine
    produces byte-identical token streams — the wire protocol carries
    admissions/sampling state losslessly."""
    cfg, params = tiny
    reqs = [RequestSpec(rid=i,
                            prompt=np.arange(3 + i, 13 + i, dtype=np.int32),
                            max_tokens=6,
                            sampling=SamplingParams(temperature=0.9, top_k=12,
                                                    seed=21 + i))
                    for i in range(3)]
    ref = _reference_outputs(cfg, params, reqs)
    from repro.serving.remote_engine import EngineProxy
    px = EngineProxy(cfg, params, max_batch=3, max_len=64, block_size=8)
    try:
        for r in reqs:
            px.submit(r)
        done = []
        for _ in range(40):
            done += px.step()
            if not px.active_rids() and px.queue_len() == 0:
                break
        assert {r.rid: r.generated for r in done} == ref
    finally:
        px.close()
