"""Per-kernel shape/dtype sweeps, allclose against the ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import (ref_decode_attention, ref_flash_attention,
                               ref_ssd)
from repro.kernels.ssd_scan import ssd_scan

K = [jax.random.PRNGKey(i) for i in range(4)]
TOL = {jnp.float32: dict(rtol=3e-5, atol=3e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,S,D", [
    (2, 4, 4, 256, 64),     # MHA
    (1, 8, 2, 128, 128),    # GQA
    (2, 4, 1, 256, 64),     # MQA
    (1, 2, 2, 512, 256),    # gemma-wide heads
])
def test_flash_attention(B, H, KV, S, D, dtype):
    q = jax.random.normal(K[0], (B, H, S, D), dtype)
    k = jax.random.normal(K[1], (B, KV, S, D), dtype)
    v = jax.random.normal(K[2], (B, KV, S, D), dtype)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    ref = ref_flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,S,D", [
    (2, 8, 2, 256, 64),
    (3, 4, 4, 128, 128),
    (1, 16, 1, 384, 64),
])
def test_decode_attention(B, H, KV, S, D, dtype):
    q = jax.random.normal(K[0], (B, H, D), dtype)
    k = jax.random.normal(K[1], (B, KV, S, D), dtype)
    v = jax.random.normal(K[2], (B, KV, S, D), dtype)
    lengths = jnp.arange(1, B + 1, dtype=jnp.int32) * (S // (B + 1)) + 1
    out = decode_attention(q, k, v, lengths, interpret=True)
    ref = ref_decode_attention(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("b,L,H,G,P,N,Q", [
    (2, 256, 4, 1, 64, 32, 64),
    (1, 128, 8, 2, 32, 16, 128),   # single-chunk
    (2, 512, 4, 4, 64, 64, 256),   # per-head groups
    (1, 256, 2, 1, 64, 128, 128),  # mamba2-780m-like state
])
def test_ssd_scan(b, L, H, G, P, N, Q, dtype):
    x = jax.random.normal(K[0], (b, L, H, P), dtype) * 0.5
    dt = jax.nn.softplus(jax.random.normal(K[1], (b, L, H), dtype))
    A = -jnp.exp(jax.random.normal(K[2], (H,), jnp.float32) * 0.3)
    B_ = jax.random.normal(K[3], (b, L, G, N), dtype) * 0.3
    C_ = jax.random.normal(K[0], (b, L, G, N), dtype) * 0.3
    y, h = ssd_scan(x, dt, A, B_, C_, chunk=Q, interpret=True)
    yr, hr = ref_ssd(x, dt, A, B_, C_)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=4e-4, atol=4e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=4e-4, atol=4e-4)


def test_ssd_matches_model_chunked_form():
    """Kernel vs the model's own chunked jnp path (a second oracle)."""
    from repro.models.ssm import ssd_chunked
    b, L, H, G, P, N = 1, 256, 4, 1, 32, 16
    x = jax.random.normal(K[0], (b, L, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(K[1], (b, L, H)))
    A = -jnp.exp(jax.random.normal(K[2], (H,)) * 0.3)
    B_ = jax.random.normal(K[3], (b, L, G, N)) * 0.3
    C_ = jax.random.normal(K[0], (b, L, G, N)) * 0.3
    y1, h1 = ssd_scan(x, dt, A, B_, C_, chunk=64, interpret=True)
    y2, h2 = ssd_chunked(x, dt, A, B_, C_, 64)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=3e-4, atol=3e-4)


def test_ops_wrappers_pad_paths():
    """Non-block-multiple shapes exercise the wrapper padding."""
    B, S, H, KV, D = 2, 200, 4, 2, 64
    q = jax.random.normal(K[0], (B, S, H, D))
    k = jax.random.normal(K[1], (B, S, KV, D))
    v = jax.random.normal(K[2], (B, S, KV, D))
    out = ops.flash_attention_bshd(q, k, v)
    ref = ref_flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)
    lens = jnp.array([150, 99], jnp.int32)
    qd = jax.random.normal(K[0], (B, 1, H, D))
    outd = ops.decode_attention_bshd(qd, k, v, lens)
    refd = ref_decode_attention(qd[:, 0], k.transpose(0, 2, 1, 3),
                                v.transpose(0, 2, 1, 3), lens)
    np.testing.assert_allclose(np.asarray(outd[:, 0]), np.asarray(refd),
                               rtol=3e-5, atol=3e-5)
