"""Minimal stand-in for the ``hypothesis`` package.

The test container does not ship ``hypothesis`` (and installing packages is
off-limits), which made every property-test module fail at *collection* —
taking the whole tier-1 run down with it. This stub implements just the
surface the suite uses (``given``, ``settings``, ``strategies.integers/
floats/lists/tuples``) with a deterministic PRNG, so property tests run as plain
randomized tests. When the real package is importable, ``conftest.py``
leaves it alone and this file is inert.
"""
from __future__ import annotations

import random
import types

# Keep stubbed property tests cheap: the real hypothesis shrinks failures,
# we just sample. Enough examples to exercise the invariant, few enough to
# keep tier-1 fast.
_MAX_EXAMPLES_CAP = 16


class _Strategy:
    def __init__(self, sample):
        self.sample = sample


def integers(min_value, max_value):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def floats(min_value, max_value):
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def tuples(*elements):
    return _Strategy(lambda r: tuple(e.sample(r) for e in elements))


def lists(elements, min_size=0, max_size=10):
    def sample(r):
        n = r.randint(min_size, max_size)
        return [elements.sample(r) for _ in range(n)]
    return _Strategy(sample)


def settings(max_examples=10, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*strategies, **kw_strategies):
    def deco(fn):
        # NOTE: no functools.wraps — it would expose fn's signature and
        # make pytest treat the drawn parameters as fixture requests.
        def wrapper(*args, **kwargs):
            n = getattr(fn, "_stub_max_examples",
                        getattr(wrapper, "_stub_max_examples", 10))
            n = min(n, _MAX_EXAMPLES_CAP)
            r = random.Random(0)
            for _ in range(n):
                drawn = [s.sample(r) for s in strategies]
                drawn_kw = {k: s.sample(r) for k, s in kw_strategies.items()}
                fn(*args, *drawn, **kwargs, **drawn_kw)
        wrapper.__name__ = getattr(fn, "__name__", "wrapped")
        wrapper.__doc__ = getattr(fn, "__doc__", None)
        # pytest plugins (anyio) introspect `.hypothesis.inner_test`
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper
    return deco


def install(sys_modules):
    """Register this stub as ``hypothesis`` + ``hypothesis.strategies``."""
    pkg = types.ModuleType("hypothesis")
    pkg.given = given
    pkg.settings = settings
    strat = types.ModuleType("hypothesis.strategies")
    strat.integers = integers
    strat.floats = floats
    strat.lists = lists
    strat.tuples = tuples
    pkg.strategies = strat
    sys_modules["hypothesis"] = pkg
    sys_modules["hypothesis.strategies"] = strat
