"""Serving substrate: engine correctness, KV accounting, simulator claims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import kvcache as KV
from repro.serving.engine import Engine
from repro.serving.request import RequestSpec
from repro.serving.simulator import SimConfig, simulate
from repro.serving.workload import WorkloadConfig, generate

KEY = jax.random.PRNGKey(0)


def test_engine_matches_direct_decode():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, KEY, "float32")
    rng = np.random.default_rng(0)
    prompt = rng.integers(2, cfg.vocab_size, size=8).astype(np.int32)
    eng = Engine(cfg, params, max_batch=2, max_len=64)
    eng.submit(RequestSpec(rid=0, prompt=prompt, max_tokens=5))
    out = eng.run_until_done()[0].generated

    cache = T.init_cache(cfg, 1, 64, "float32")
    lg, cache, _ = T.forward(params, cfg, jnp.asarray(prompt)[None],
                             mode="prefill", cache=cache)
    ref = [int(jnp.argmax(lg[0, :cfg.vocab_size]))]
    for i in range(4):
        pos = jnp.full((1, 1), 8 + i, jnp.int32)
        lg, cache, _ = T.forward(params, cfg,
                                 jnp.asarray([[ref[-1]]], jnp.int32),
                                 positions=pos, mode="decode", cache=cache)
        ref.append(int(jnp.argmax(lg[0, :cfg.vocab_size])))
    assert out == ref


def test_engine_interleaved_batching_isolated():
    """Interleaved requests must not perturb each other's outputs."""
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, KEY, "float32")
    rng = np.random.default_rng(1)
    prompts = [rng.integers(2, cfg.vocab_size, size=6).astype(np.int32)
               for _ in range(4)]
    # run alone
    solo = []
    for i, p in enumerate(prompts):
        e = Engine(cfg, params, max_batch=1, max_len=64)
        e.submit(RequestSpec(rid=i, prompt=p, max_tokens=4))
        solo.append(e.run_until_done()[0].generated)
    # run together with 2 slots (forces queueing + slot reuse)
    e = Engine(cfg, params, max_batch=2, max_len=64)
    for i, p in enumerate(prompts):
        e.submit(RequestSpec(rid=i, prompt=p, max_tokens=4))
    done = {r.rid: r.generated for r in e.run_until_done()}
    for i in range(4):
        assert done[i] == solo[i], f"request {i} perturbed by batching"


@pytest.mark.parametrize("cache_kind", ["dense", "paged"])
def test_first_token_can_finish_request(cache_kind):
    """max_tokens=1 is satisfied by the admission-sampled token: the
    request retires without ever occupying a decode slot."""
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, KEY, "float32")
    kw = {"block_size": 8} if cache_kind == "paged" else {}
    eng = Engine(cfg, params, max_batch=2, max_len=64,
                 cache_kind=cache_kind, **kw)
    eng.submit(RequestSpec(rid=0, prompt=np.arange(2, 10).astype(np.int32),
                       max_tokens=1))
    done = eng.run_until_done()
    assert len(done) == 1 and len(done[0].generated) == 1
    assert not eng.active
    if cache_kind == "paged":
        assert eng.pstate.blocks_in_use() == 0


def test_kv_bytes_per_token():
    llama = get_config("llama2-13b")
    per_tok = KV.kv_bytes_per_token(llama)
    # 40 layers * 2 * 40 heads * 128 dim * 2 bytes = 819200
    assert per_tok == 40 * 2 * 40 * 128 * 2
    assert KV.kv_bytes_per_token(get_config("mamba2-780m")) == 0
    mla = get_config("minicpm3-4b")
    assert KV.kv_bytes_per_token(mla) == 62 * (256 + 32) * 2


def test_state_bytes_ssm():
    cfg = get_config("mamba2-780m")
    b = KV.state_bytes(cfg)
    assert b > 0
    # O(1): independent of any sequence length notion
    assert b < 100e6


def test_workload_deterministic():
    wl = WorkloadConfig(rps=10, duration_s=5, seed=3)
    a, b = generate(wl), generate(wl)
    assert len(a) == len(b) and all(x.arrival == y.arrival
                                    for x, y in zip(a, b))
    assert all(r.output_len <= wl.max_output for r in a)


@pytest.mark.parametrize("system", ["hft", "vllm", "cocoserve"])
def test_simulator_runs(system):
    cfg = get_config("llama2-13b")
    r = simulate(SimConfig(model=cfg, system=system, n_devices=4),
                 WorkloadConfig(rps=8, duration_s=5.0, seed=0))
    assert r.sim_time > 0
    assert len(r.completed) + r.dropped > 0


def test_simulator_paper_orderings():
    """The paper's qualitative claims, on a short workload:
    latency(coco) <= latency(vllm) < latency(hft); oom(hft) > oom(coco)."""
    cfg = get_config("llama2-13b")
    res = {}
    for system in ("hft", "vllm", "cocoserve"):
        res[system] = simulate(
            SimConfig(model=cfg, system=system, n_devices=4),
            WorkloadConfig(rps=30, duration_s=10.0, seed=0))
    assert res["cocoserve"].mean_latency <= res["vllm"].mean_latency * 1.01
    assert res["cocoserve"].mean_latency < res["hft"].mean_latency
    assert res["cocoserve"].throughput_tokens > res["hft"].throughput_tokens
    assert res["hft"].oom_events > res["cocoserve"].oom_events
    assert (res["cocoserve"].slo_attainment(12.0)
            >= res["hft"].slo_attainment(12.0))


def test_cocoserve_controller_acts_in_sim():
    cfg = get_config("llama2-13b")
    r = simulate(SimConfig(model=cfg, system="cocoserve", n_devices=4),
                 WorkloadConfig(rps=20, duration_s=10.0, seed=0))
    assert len(r.controller_log) >= 1  # scale-up fired at least once
