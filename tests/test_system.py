"""End-to-end behaviour tests for the CoCoServe reproduction.

The full loop the paper describes: serve with continuous batching, monitor,
auto-scale (up via Alg. 1 replication, down via Alg. 2 module reduction),
and the speedup model that drives both — checked against the paper's own
qualitative claims at system level.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.cluster import Cluster, layer_weight_bytes, module_profile
from repro.core.controller import Controller, ControllerConfig
from repro.core.monitor import MetricsSnapshot, Monitor
from repro.core.plan import PlacementPlan
from repro.core.speedup import speedup_homo
from repro.models import transformer as T
from repro.serving.engine import Engine
from repro.serving.request import RequestSpec, SamplingParams
from repro.serving.simulator import SimConfig, simulate
from repro.serving.workload import WorkloadConfig


def test_table1_module_analysis_matches_paper():
    """Paper Table 1 (LLaMA-13B, bs=1, seq=256): projection 50 MB /
    13.42 GFLOPs, self_attn 200 MB / 55.02 GFLOPs (incl. scores),
    ffn 135 MB / 36.24 GFLOPs, decoder layer 605 MB / 127.5 GFLOPs."""
    cfg = get_config("llama2-13b")
    prof = module_profile(cfg, batch=1, seq=256)
    MB, G = 1e6, 1e9
    assert prof["self_attn.q/k/v/o_proj"]["mem"] / MB == pytest.approx(52.4, rel=0.1)
    assert prof["self_attn.q/k/v/o_proj"]["flops"] / G == pytest.approx(13.42, rel=0.05)
    assert prof["self_attn"]["mem"] / MB == pytest.approx(200, rel=0.1)
    attn_total = (prof["self_attn"]["flops"]
                  + prof["self_attn"]["extra_flops_scores"])
    assert attn_total / G == pytest.approx(55.02, rel=0.1)
    assert prof["ffn.gate/up/down_proj"]["mem"] / MB == pytest.approx(135, rel=0.1)
    assert prof["ffn.gate/up/down_proj"]["flops"] / G == pytest.approx(36.24, rel=0.05)
    assert prof["decoder_layer"]["mem"] / MB == pytest.approx(605, rel=0.15)
    assert prof["decoder_layer"]["flops"] / G == pytest.approx(127.5, rel=0.35)


def test_closed_loop_scaleup_accelerates_model():
    """Controller observes vacancy -> replicates layers -> modeled speedup
    exceeds 1 and continuity is preserved."""
    cluster = Cluster.homogeneous(4)
    plan = PlacementPlan.initial(22)
    mon = Monitor()
    mon.record(MetricsSnapshot(t=0, slo_violation_rate=0.0,
                               device_util=[0.6, 0.05, 0.05, 0.05],
                               device_mem_frac=[0.6, 0.1, 0.1, 0.1]))
    ctrl = Controller(ControllerConfig(replica_size=605e6, gamma=0.05),
                      cluster, plan, mon)
    assert ctrl.tick().startswith("scale-up")
    sp = speedup_homo(ctrl.plan.p, 0.05)
    assert sp > 1.2
    assert ctrl.plan.continuity_breaks() <= 6


def test_full_serving_session_with_scaling():
    """Real engine closed loop completes all requests correctly."""
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), "float32")
    eng = Engine(cfg, params, max_batch=3, max_len=64)
    rng = np.random.default_rng(0)
    n = 10
    for i in range(n):
        eng.submit(RequestSpec(rid=i,
                           prompt=rng.integers(2, cfg.vocab_size,
                                               size=8).astype(np.int32),
                           max_tokens=5))
    done = eng.run_until_done()
    assert len(done) == n
    assert all(len(r.generated) == 5 for r in done)


def test_full_serving_session_paged():
    """Same closed loop on the primary (paged) path, with stochastic
    sampling mixed in — all requests complete at full length."""
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), "float32")
    eng = Engine(cfg, params, max_batch=3, max_len=64, cache_kind="paged",
                 block_size=8)
    rng = np.random.default_rng(0)
    n = 8
    for i in range(n):
        eng.submit(RequestSpec(
            rid=i,
            prompt=rng.integers(2, cfg.vocab_size,
                                size=6 + i % 3).astype(np.int32),
            max_tokens=5,
            sampling=SamplingParams(temperature=0.8 if i % 2 else 0.0,
                                    top_k=16, seed=i)))
    done = eng.run_until_done()
    assert len(done) == n
    assert all(len(r.generated) == 5 for r in done)
    assert all(0 <= t < cfg.vocab_size for r in done for t in r.generated)
    # the pool drained back to empty
    assert eng.pstate.blocks_in_use() == 0


@pytest.mark.parametrize("cache_kind", ["dense", "paged"])
def test_engine_single_host_sync_per_step(cache_kind):
    """The fused decode+sample step performs at most ONE device->host
    sync (the sampled-token fetch) — the acceptance bound for the paged
    path; the dense path shares the same fused step shape."""
    from repro.serving.instrument import count_host_syncs
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), "float32")
    kw = {"block_size": 8} if cache_kind == "paged" else {}
    eng = Engine(cfg, params, max_batch=4, max_len=64,
                 cache_kind=cache_kind, **kw)
    rng = np.random.default_rng(1)
    for i in range(4):
        eng.submit(RequestSpec(rid=i,
                           prompt=rng.integers(2, cfg.vocab_size, size=7)
                           .astype(np.int32), max_tokens=16))
    eng.step()  # admissions (prefill syncs allowed here)
    for _ in range(4):  # steady-state decode
        with count_host_syncs() as c:
            eng.step()
        assert c.n <= 1, f"{cache_kind} step made {c.n} host syncs"


def test_cost_reduction_claim():
    """Paper §6.3: CoCoServe's 2-instance deployment delivers ~90% of a
    4-instance HFT's performance at roughly half its memory (cost -46%)."""
    cfg = get_config("llama2-13b")
    wl = WorkloadConfig(rps=20, duration_s=10.0, seed=0)
    coco2 = simulate(SimConfig(model=cfg, system="cocoserve", n_devices=4,
                               n_instances=2), wl)
    hft4 = simulate(SimConfig(model=cfg, system="hft", n_devices=4,
                              n_instances=4), wl)
    mem_coco = sum(coco2.peak_mem_per_device)
    mem_hft = sum(hft4.peak_mem_per_device)
    assert mem_coco < 0.75 * mem_hft          # substantial memory saving
    assert (coco2.throughput_tokens
            >= 0.9 * hft4.throughput_tokens)  # near-equivalent performance


def test_scaling_cost_sub_second():
    """Paper Table 2: module ops stay sub-second up to 40 layers."""
    from repro.core.migration import estimate_cost
    cfg = get_config("llama2-13b")
    per_layer = layer_weight_bytes(cfg)
    for n in (1, 10, 20, 40):
        t = estimate_cost(n * per_layer, link_bandwidth=64e9)
        assert t < 1.0, f"{n} layers took {t:.2f}s"
    assert estimate_cost(1 * per_layer, 64e9) < estimate_cost(40 * per_layer,
                                                              64e9)
