"""Prefix sharing with copy-on-write block refcounts (ISSUE-3 tentpole).

Pool-level: refcount lifecycle (free -> owned -> shared -> CoW-forked),
cached-free revival and LRU eviction, wire-format key carriage.
Engine-level: shared-system-prompt admissions alias cached blocks, skip
the shared span's prefill, stay token-identical to sharing-off (greedy
AND sampled), and use measurably fewer pool blocks. Orchestrator-level:
scale-down migration of streams holding shared blocks stays zero-drop
and token-identical.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import paged_kv as PK
from repro.serving.engine import Engine
from repro.serving.orchestrator import Orchestrator
from repro.serving.request import RequestSpec, SamplingParams

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, KEY, "float32")
    return cfg, params


def _check_invariants(st: PK.PagedState):
    """The PagedState refcount invariants from the dataclass docstring."""
    held = np.zeros(st.n_blocks, np.int64)
    for row in st.block_tables:
        for b in row:
            if b >= 0:
                held[b] += 1
    np.testing.assert_array_equal(held, st.refcount)
    for b in st.free:
        assert st.refcount[b] == 0 and b not in st.block_key
    for b in st.cached_free:
        assert st.refcount[b] == 0 and b in st.block_key
    for key, b in st.prefix_cache.items():
        assert st.block_key[b] == key
    assert len(st.prefix_cache) == len(st.block_key)


# ------------------------------------------------------------- pool level
def test_refcount_lifecycle(tiny):
    """free -> owned -> shared -> cached-free -> revived -> evicted."""
    cfg, _ = tiny
    st = PK.init_paged(cfg, 3, 8, block_size=4, dtype="float32",
                       max_len=32, prefix_cache=True)
    toks = np.arange(2, 11, dtype=np.int32)         # 9 tokens: 2 full blocks
    PK.allocate(st, 0, len(toks))
    assert st.blocks_in_use() == 3                  # cols 0,1,2 owned
    assert PK.register_prefix(st, 0, toks) == 2     # partial col 2 skipped
    _check_invariants(st)

    # a second slot with the same prompt aliases both full blocks
    matched = PK.match_prefix(st, toks)
    assert len(matched) == 2
    PK.adopt_prefix(st, 1, matched, 8)
    assert st.shared_blocks_saved() == 2
    assert st.blocks_in_use() == 3                  # no new physical block
    _check_invariants(st)

    # owner leaves: shared blocks survive, its private tail returns
    PK.free_slot(st, 0)
    assert st.shared_blocks_saved() == 0            # refcounts back to 1
    assert st.blocks_in_use() == 2
    _check_invariants(st)

    # last holder leaves: registered blocks PARK on cached_free...
    PK.free_slot(st, 1)
    assert st.blocks_in_use() == 0
    assert len(st.cached_free) == 2
    # ...and a fresh match still revives them
    revived = PK.match_prefix(st, toks)
    assert revived == matched
    PK.adopt_prefix(st, 2, revived, 8)
    assert not st.cached_free
    _check_invariants(st)
    PK.free_slot(st, 2)

    # allocation pressure evicts cached-free blocks (oldest first) and
    # drops their cache entries — the pool never refuses while they exist
    PK.allocate(st, 0, 8 * 4)                       # claim the whole pool
    assert st.blocks_in_use() == 8
    assert not st.prefix_cache and not st.cached_free
    _check_invariants(st)
    with pytest.raises(PK.OutOfBlocks):
        PK.allocate(st, 1, 4)


def test_cow_fork_isolates_writer(tiny):
    """ensure_writable forks a shared block: the writer gets a private
    copy (same content), the co-holder's view is untouched."""
    cfg, _ = tiny
    st = PK.init_paged(cfg, 2, 8, block_size=4, dtype="float32",
                       max_len=32, prefix_cache=True)
    L, KV, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    rng = np.random.default_rng(0)
    toks = rng.integers(2, 100, size=8).astype(np.int32)
    kv = jnp.asarray(rng.normal(size=(L, 8, KV, hd)), jnp.float32)
    PK.allocate(st, 0, 8)
    st = PK.write_tokens(st, 0, kv, kv * 2)
    PK.register_prefix(st, 0, toks)
    PK.adopt_prefix(st, 1, PK.match_prefix(st, toks), 7)

    assert PK.ensure_writable(st, 1, 7, 1) == 1     # forks shared col 1
    assert st.cow_forks == 1
    assert st.refcount[st.block_tables[0, 1]] == 1
    assert st.block_tables[0, 1] != st.block_tables[1, 1]
    assert st.block_tables[0, 0] == st.block_tables[1, 0]  # col 0 untouched
    _check_invariants(st)
    # fork copied content; owner's blocks still hold the original
    k0, _ = PK.gather_request(st, 0, 8)
    k1, _ = PK.gather_request(st, 1, 8)
    np.testing.assert_array_equal(np.asarray(k0), np.asarray(k1))
    # owned (refcount-1) columns are never forked
    assert PK.ensure_writable(st, 1, 7, 1) == 0


def test_out_of_window_release_is_decref(tiny):
    """A shared block going out of one stream's window survives for the
    other holder instead of returning to the free list."""
    cfg, _ = tiny
    st = PK.init_paged(cfg, 2, 8, block_size=4, dtype="float32",
                       max_len=64, prefix_cache=True)
    toks = np.arange(2, 10, dtype=np.int32)
    PK.allocate(st, 0, 8)
    PK.register_prefix(st, 0, toks)
    PK.adopt_prefix(st, 1, PK.match_prefix(st, toks), 8)
    shared0 = int(st.block_tables[0, 0])
    st.lengths[0] = 9                       # pretend slot 0 decoded past
    assert PK.free_out_of_window(st, 0, window=4) == 1
    assert st.refcount[shared0] == 1        # slot 1 still holds it
    assert st.block_tables[1, 0] == shared0
    _check_invariants(st)


def test_export_import_carries_prefix_keys(tiny):
    """The migration wire format materializes shared blocks and re-seeds
    the destination's prefix cache from the carried keys."""
    cfg, _ = tiny
    src = PK.init_paged(cfg, 2, 8, block_size=4, dtype="float32",
                        max_len=32, prefix_cache=True)
    dst = PK.init_paged(cfg, 2, 8, block_size=4, dtype="float32",
                        max_len=32, prefix_cache=True)
    L, KV, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    rng = np.random.default_rng(1)
    toks = rng.integers(2, 100, size=9).astype(np.int32)
    kv = jnp.asarray(rng.normal(size=(L, 9, KV, hd)), jnp.float32)
    PK.allocate(src, 0, 9)
    src = PK.write_tokens(src, 0, kv, kv * 2)
    PK.register_prefix(src, 0, toks)
    PK.adopt_prefix(src, 1, PK.match_prefix(src, toks), 8)  # now SHARED

    payload = PK.export_blocks(src, 0)
    assert len(payload["keys"]) == 2                # the 2 full blocks
    before_k, _ = PK.gather_request(src, 0, 9)
    PK.import_blocks(dst, 0, payload)
    after_k, _ = PK.gather_request(dst, 0, 9)
    np.testing.assert_array_equal(np.asarray(before_k), np.asarray(after_k))
    _check_invariants(dst)
    # the destination now serves the migrated prompt from its own cache
    assert len(PK.match_prefix(dst, toks)) == 2
    # source co-holder unaffected by releasing the migrated slot
    PK.free_slot(src, 0)
    assert src.refcount[src.block_tables[1, 0]] == 1
    _check_invariants(src)


# ----------------------------------------------------------- engine level
def _shared_prompt_requests(cfg, n, sys_len=24, temp=0.0, top_k=0,
                            max_new=6):
    rng = np.random.default_rng(7)
    sys_prompt = rng.integers(2, cfg.vocab_size, size=sys_len).astype(np.int32)
    reqs = []
    for i in range(n):
        user = rng.integers(2, cfg.vocab_size, size=3 + i).astype(np.int32)
        reqs.append(RequestSpec(
            rid=i, prompt=np.concatenate([sys_prompt, user]),
            max_tokens=max_new,
            sampling=SamplingParams(temperature=temp, top_k=top_k,
                                    seed=5 + i)))
    return reqs


def _run_engine(cfg, params, reqs, share, **kw):
    eng = Engine(cfg, params, max_batch=4, max_len=64, cache_kind="paged",
                 block_size=8, prefix_sharing=share, **kw)
    for r in reqs:
        eng.submit(r)
    peak, done = 0, []
    while eng.queue or eng.active:
        done += eng.step() or []
        peak = max(peak, eng.pstate.blocks_in_use())
    return {r.rid: r.generated for r in done}, peak, eng


@pytest.mark.parametrize("temperature,top_k", [(0.0, 0), (0.8, 16)])
def test_sharing_token_identical_and_saves_blocks(tiny, temperature, top_k):
    """The acceptance bar: sharing ON equals sharing OFF token-for-token
    (greedy and sampled) while a shared-system-prompt workload holds
    measurably fewer pool blocks."""
    cfg, params = tiny

    def reqs():
        return _shared_prompt_requests(cfg, 6, temp=temperature, top_k=top_k)

    off, peak_off, _ = _run_engine(cfg, params, reqs(), share=False)
    on, peak_on, eng = _run_engine(cfg, params, reqs(), share=True)
    assert on == off
    assert peak_on < peak_off, (peak_on, peak_off)
    stats = eng.prefix_stats()
    assert stats["hits"] > 0 and stats["hit_rate"] > 0.5
    assert stats["blocks_saved_total"] > 0
    assert eng.pstate.blocks_in_use() == 0          # fully drained
    _check_invariants(eng.pstate)


def test_aligned_duplicate_prompt_triggers_cow(tiny):
    """Identical block-aligned prompts alias EVERY prompt block; the
    recomputed last token's write forks the shared tail (copy-on-write)
    and the streams still match the unshared run exactly."""
    cfg, params = tiny
    prompt = np.random.default_rng(3).integers(
        2, cfg.vocab_size, size=16).astype(np.int32)

    def dup():
        return [RequestSpec(rid=i, prompt=prompt.copy(), max_tokens=5)
                for i in range(2)]

    on, _, eng = _run_engine(cfg, params, dup(), share=True)
    off, _, _ = _run_engine(cfg, params, dup(), share=False)
    assert on == off
    assert eng.pstate.cow_forks >= 1
    assert eng.pstate.blocks_in_use() == 0
    _check_invariants(eng.pstate)


def test_sharing_with_preemption_replays_identically(tiny):
    """Pool pressure preempts a stream holding shared blocks: decref on
    eviction + cache-hit on re-admission keep outputs identical to an
    unconstrained pool."""
    cfg, params = tiny
    reqs = _shared_prompt_requests(cfg, 4, sys_len=16, max_new=16)
    big, _, _ = _run_engine(cfg, params, list(reqs),
                            share=True)
    # a pool too small for all four: forces preemption mid-decode
    small, _, eng = _run_engine(cfg, params, list(reqs),
                                share=True, n_blocks=11)
    assert small == big
    assert eng.preempt_count > 0, "scenario exercised no preemption"
    assert eng.pstate.blocks_in_use() == 0
    _check_invariants(eng.pstate)


def test_sharing_skips_prefill_compute_for_shared_span(tiny):
    """A cache-hit admission compiles/pays only the SUFFIX prefill: the
    padded prefill shapes it adds are suffix-sized, far below the full
    prompt bucket."""
    cfg, params = tiny
    reqs = _shared_prompt_requests(cfg, 2, sys_len=32)   # 35/36-token prompts
    _, _, eng = _run_engine(cfg, params, reqs, share=True)
    shapes = eng._prefill_shapes
    full = [S for _, S in shapes if S >= 64]     # rid 0's full-prompt bucket
    suffix = [S for _, S in shapes if S <= 16]   # rid 1's suffix-only bucket
    assert full and suffix, shapes


def test_hit_admits_under_pressure_that_stalls_cold_request(tiny):
    """Backpressure accounts for aliasing: a request whose prefix is
    RESIDENT (held by an active stream) admits when the pool only has
    room for its suffix — the same request without sharing stays queued
    until the holder finishes."""
    cfg, params = tiny
    rng = np.random.default_rng(5)
    sys_prompt = rng.integers(2, cfg.vocab_size, size=16).astype(np.int32)
    users = [rng.integers(2, cfg.vocab_size, size=5).astype(np.int32)
             for _ in range(2)]

    def run(share):
        # 5-block pool: the holder takes 3, a COLD 21-token admission
        # wants blocks_needed=3 > 2 free; the suffix alone needs 1
        eng = Engine(cfg, params, max_batch=2, max_len=64,
                     cache_kind="paged", block_size=8, n_blocks=5,
                     prefix_sharing=share)
        eng.submit(RequestSpec(rid=0,
                           prompt=np.concatenate([sys_prompt, users[0]]),
                           max_tokens=3))
        eng.step()                         # rid 0 admitted, holds 3 blocks
        assert 0 in {r.rid for r in eng.active.values()}
        eng.submit(RequestSpec(rid=1,
                           prompt=np.concatenate([sys_prompt, users[1]]),
                           max_tokens=3))
        eng.step()
        admitted = 1 in {r.rid for r in eng.active.values()}
        done = eng.run_until_done()
        return admitted, {r.rid: r.generated for r in done}

    stalled_admit, off = run(share=False)
    shared_admit, on = run(share=True)
    assert not stalled_admit, "cold request should stall on a full pool"
    assert shared_admit, "aliased request should admit alongside holder"
    assert on == off                        # and still token-identical


# ----------------------------------------------------- orchestrator level
def test_migration_of_shared_blocks_token_identical(tiny):
    """Scale-down migration of a stream whose blocks are SHARED with a
    stream staying behind: zero drops, token-identical on both sides, and
    the destination learns the prefix for later admissions."""
    cfg, params = tiny
    rng = np.random.default_rng(11)
    sys_prompt = rng.integers(2, cfg.vocab_size, size=16).astype(np.int32)
    reqs = [RequestSpec(rid=i,
                        prompt=np.concatenate(
                            [sys_prompt,
                             rng.integers(2, cfg.vocab_size,
                                          size=4 + i).astype(np.int32)]),
                        max_tokens=10,
                        sampling=SamplingParams(temperature=0.8, top_k=16,
                                                seed=3 + i))
            for i in range(2)]

    # unmigrated oracle: each request solo on a fresh engine
    ref = {}
    for r in reqs:
        e = Engine(cfg, params, max_batch=1, max_len=64,
                   cache_kind="paged", block_size=8)
        e.submit(r)
        ref[r.rid] = e.run_until_done()[0].generated

    orch = Orchestrator(cfg, params, n_instances=2, max_batch=2,
                        max_len=64, block_size=8, n_blocks=24,
                        telemetry_every=10_000)
    for r in reqs:
        orch._home[r.rid] = 0
        orch.engines[0].submit(r)               # both on A: blocks shared
    for _ in range(4):
        orch.step()
    assert orch.engines[0].pstate.shared_blocks_saved() > 0, \
        "scenario exercised no sharing"
    # migrate ONLY rid 0; rid 1 keeps its claim on the shared blocks
    recs = orch.migrate_requests(0, 1, max_requests=1)
    assert len(recs) == 1 and recs[0].resumed and recs[0].rid == 0
    done = {r.rid: r.generated for r in orch.run_until_done()}
    assert done == ref
    assert orch.dropped == 0
    for e in orch.engines:
        assert e.pstate.blocks_in_use() == 0
        _check_invariants(e.pstate)
    snap = orch.snapshot()
    assert snap.prefix_hit_rate >= 0.0          # gauge surfaced
    assert orch.stats()["prefix_hit_rate"] > 0.0


def test_import_dedupes_resident_prefix_blocks(tiny):
    """Cross-instance dedupe: importing a payload whose carried prefix
    key is already RESIDENT in the destination cache aliases (increfs)
    the resident block instead of materializing a duplicate copy — and
    the aliased column behaves like any shared block (CoW on write,
    decref on release)."""
    cfg, _ = tiny
    L, KV, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    rng = np.random.default_rng(2)
    toks = rng.integers(2, 100, size=9).astype(np.int32)  # 2 full blocks
    kv = jnp.asarray(rng.normal(size=(L, 9, KV, hd)), jnp.float32)

    def fresh_pool():
        st = PK.init_paged(cfg, 3, 12, block_size=4, dtype="float32",
                           max_len=32, prefix_cache=True)
        PK.allocate(st, 0, 9)
        st = PK.write_tokens(st, 0, kv, kv * 2)
        PK.register_prefix(st, 0, toks)
        return st

    src, dst = fresh_pool(), fresh_pool()   # dst already serves the prompt
    payload = PK.export_blocks(src, 0)
    used_before = dst.blocks_in_use()
    PK.import_blocks(dst, 1, payload)
    # only the partial tail materialized; the 2 full blocks aliased
    assert dst.dedup_imports == 2
    assert dst.blocks_in_use() == used_before + 1
    assert dst.shared_blocks_saved() == 2
    _check_invariants(dst)
    # aliased content is exactly the payload content
    want_k, _ = PK.gather_request(src, 0, 9)
    got_k, _ = PK.gather_request(dst, 1, 9)
    np.testing.assert_array_equal(np.asarray(want_k), np.asarray(got_k))
    # a write into an ALIASED column forks, leaving slot 0's view intact
    # (position 7 = last row of aliased block 1; the materialized tail at
    # column 2 is owned and would need no fork)
    assert PK.ensure_writable(dst, 1, 7, 1) == 1
    _check_invariants(dst)
    PK.free_slot(dst, 1)
    _check_invariants(dst)


def test_migration_dedupe_end_to_end_token_identical(tiny):
    """Orchestrator-level dedupe: migrate a stream whose system prompt
    is ALREADY resident at the destination (another stream with the same
    prefix lives there) — the import aliases instead of copying, pools
    stay consistent, and every stream is token-identical."""
    cfg, params = tiny
    rng = np.random.default_rng(13)
    sys_prompt = rng.integers(2, cfg.vocab_size, size=16).astype(np.int32)
    reqs = [RequestSpec(rid=i,
                        prompt=np.concatenate(
                            [sys_prompt,
                             rng.integers(2, cfg.vocab_size,
                                          size=4 + i).astype(np.int32)]),
                        max_tokens=8,
                        sampling=SamplingParams(temperature=0.8, top_k=16,
                                                seed=3 + i))
            for i in range(2)]
    ref = {}
    for r in reqs:
        e = Engine(cfg, params, max_batch=1, max_len=64,
                   cache_kind="paged", block_size=8)
        e.submit(r)
        ref[r.rid] = e.run_until_done()[0].generated

    orch = Orchestrator(cfg, params, n_instances=2, max_batch=2,
                        max_len=64, block_size=8, n_blocks=24,
                        telemetry_every=10_000)
    # same system prompt on BOTH instances: rid 0 on A, rid 1 on B
    for i, r in enumerate(reqs):
        orch._home[r.rid] = i
        orch.engines[i].submit(r)
    for _ in range(3):
        orch.step()
    in_use_before = orch.engines[1].pstate.blocks_in_use()
    recs = orch.migrate_requests(0, 1)
    assert len(recs) == 1 and recs[0].resumed
    st = orch.engines[1].pstate
    assert st.dedup_imports == 2            # both full sys-prompt blocks
    assert st.blocks_in_use() < in_use_before + recs[0].n_blocks
    _check_invariants(st)
    done = {r.rid: r.generated for r in orch.run_until_done()}
    assert done == ref
    assert orch.dropped == 0
    assert orch.stats()["dedup_imports"] == 2
    for e in orch.engines:
        assert e.pstate.blocks_in_use() == 0
        _check_invariants(e.pstate)


def test_hit_suffix_prefills_are_batched(tiny):
    """Prefix-hit admissions in one wave run ONE bucketed extend per
    (context, suffix) group — the hit-path analogue of the miss wave's
    pow2 buckets — instead of one extend per hit request; outputs still
    match sharing-off exactly."""
    cfg, params = tiny
    rng = np.random.default_rng(9)
    sys_prompt = rng.integers(2, cfg.vocab_size, size=24).astype(np.int32)
    # suffix lengths 6..8 share the pow2 bucket 8 AND the context bucket:
    # one wave => first request misses, three wave-mates hit as a group
    reqs = [RequestSpec(rid=i,
                    prompt=np.concatenate(
                        [sys_prompt,
                         rng.integers(2, cfg.vocab_size,
                                      size=5 + i).astype(np.int32)]),
                    max_tokens=4)
            for i in range(4)]
    on, _, eng = _run_engine(cfg, params, list(reqs),
                             share=True)
    off, _, _ = _run_engine(cfg, params, list(reqs),
                            share=False)
    assert on == off
    grouped = [(G, S) for G, S in eng._prefill_shapes if G >= 2 and S <= 16]
    assert grouped, (
        f"hit wave did not batch: prefill shapes {eng._prefill_shapes}")
    stats = eng.prefix_stats()
    assert stats["hits"] > 0
    assert eng.pstate.blocks_in_use() == 0
    _check_invariants(eng.pstate)


def test_snapshot_surfaces_sharing_gauges(tiny):
    """MetricsSnapshot carries prefix_hit_rate/blocks_saved while streams
    are live — the controller's vacancy signal reflects sharing."""
    cfg, params = tiny
    reqs = _shared_prompt_requests(cfg, 4, sys_len=16)
    orch = Orchestrator(cfg, params, n_instances=1, max_batch=4,
                        max_len=64, block_size=8, n_blocks=32,
                        telemetry_every=10_000)
    for r in reqs:
        orch.submit(r)
    for _ in range(3):
        orch.step()
    snap = orch.snapshot()
    assert snap.prefix_hit_rate > 0.0
    assert snap.blocks_saved > 0
    # the snapshot reads the EngineTelemetry mirrors, which must agree
    # with the engines' own counters
    tel = orch.telemetry[0]
    stats = orch.engines[0].prefix_stats()
    assert tel.prefix_hit_rate() == stats["hit_rate"]
    assert tel.blocks_saved == stats["blocks_saved_now"]
    assert orch.monitor is not None
    orch.monitor.record(snap)
    assert orch.monitor.prefix_hit_rate() == snap.prefix_hit_rate
    assert orch.monitor.blocks_saved_by_sharing() == snap.blocks_saved
    orch.run_until_done()
