"""Routing policies (serving/router.py): chain-affinity selection,
vacancy fallback order, admission backpressure, determinism — all on
fake handles (the policy reads nothing but the handle gauge surface) —
plus the tier-2 pod-wide acceptance: same-prefix tenants streaming
through the REAL ingress land on their chain-holding instance >= 90% of
the time after warmup."""
import numpy as np
import pytest

from repro.serving.router import (PrefixAffinityRouter, RoundRobinRouter,
                                  RouteDecision, VacancyRouter,
                                  chain_hexkeys)

BS = 8


class FakeHandle:
    """Just the gauges the policies read."""

    def __init__(self, free=100, queue=0, keys=(), block_size=BS):
        self._free = free
        self._queue = queue
        self._keys = set(keys)
        self.block_size = block_size

    def free_blocks(self):
        return self._free

    def queue_len(self):
        return self._queue

    def prefix_keys(self):
        return self._keys


def _prompt(n_tokens, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(2, 1000, size=n_tokens).astype(np.int32)


def _holder_of(prompt, n_blocks, block_size=BS, **kw):
    """A handle whose resident set covers the prompt's first n_blocks."""
    keys = chain_hexkeys(prompt, block_size)[:n_blocks]
    return FakeHandle(keys=keys, block_size=block_size, **kw)


# ------------------------------------------------------------ chain keys
def test_chain_hexkeys_one_per_full_block_and_content_dependent():
    p = _prompt(3 * BS + 5)
    keys = chain_hexkeys(p, BS)
    assert len(keys) == 3                      # partial tail block: no key
    # chained: same first block -> same first key; divergence at block 2
    q = p.copy()
    q[BS] += 1
    keys_q = chain_hexkeys(q, BS)
    assert keys_q[0] == keys[0] and keys_q[1] != keys[1]
    # ...and the chain poisons everything downstream of the divergence
    assert keys_q[2] != keys[2]
    assert chain_hexkeys(p, 0) == [] and chain_hexkeys(None, BS) == []


# ------------------------------------------------------- affinity policy
def test_affinity_picks_the_chain_holder():
    p = _prompt(4 * BS)
    handles = [FakeHandle(free=500),           # emptier, but no match
               _holder_of(p, 4, free=10)]
    d = PrefixAffinityRouter().select(handles, [0, 1], prompt=p)
    assert d == RouteDecision(1, matched_blocks=4, reason="prefix")


def test_affinity_longest_leading_run_wins():
    p = _prompt(4 * BS)
    keys = chain_hexkeys(p, BS)
    handles = [_holder_of(p, 2),
               _holder_of(p, 3),
               # holds MORE keys but not the leading ones: a later block
               # without its predecessors certifies nothing
               FakeHandle(keys=keys[1:4])]
    d = PrefixAffinityRouter().select(handles, [0, 1, 2], prompt=p)
    assert (d.idx, d.matched_blocks, d.reason) == (1, 3, "prefix")


def test_affinity_tie_breaks_by_vacancy_order():
    p = _prompt(2 * BS)
    handles = [_holder_of(p, 2, free=10),
               _holder_of(p, 2, free=50),      # same match, more room
               _holder_of(p, 2, free=50, queue=3)]
    d = PrefixAffinityRouter().select(handles, [0, 1, 2], prompt=p)
    assert (d.idx, d.reason) == (1, "prefix")


def test_min_match_floor_falls_through_to_vacancy():
    p = _prompt(2 * BS)
    handles = [FakeHandle(free=500), _holder_of(p, 1, free=10)]
    d = PrefixAffinityRouter(min_match=2).select(handles, [0, 1], prompt=p)
    assert (d.idx, d.reason) == (0, "vacancy")
    d = PrefixAffinityRouter(min_match=1).select(handles, [0, 1], prompt=p)
    assert (d.idx, d.reason) == (1, "prefix")


def test_no_match_routes_by_vacancy_then_queue_then_index():
    handles = [FakeHandle(free=10, queue=0),
               FakeHandle(free=50, queue=9),   # most room wins regardless
               FakeHandle(free=50, queue=9)]
    d = PrefixAffinityRouter().select(handles, [0, 1, 2],
                                      prompt=_prompt(BS, seed=7))
    assert (d.idx, d.reason) == (1, "vacancy")
    # pending charges count like queue: tip the tie to idx 2
    d = VacancyRouter().select(handles, [1, 2], pending={1: 1})
    assert d.idx == 2


def test_router_is_deterministic():
    p = _prompt(3 * BS)
    handles = [FakeHandle(free=40), _holder_of(p, 3, free=40),
               FakeHandle(free=40)]
    router = PrefixAffinityRouter()
    picks = {router.select(handles, [0, 1, 2], prompt=p).idx
             for _ in range(10)}
    assert picks == {1}


def test_heterogeneous_block_sizes_hash_per_instance():
    p = _prompt(4 * BS)
    # instance 1 runs 2x the block size: its chain keys differ, and the
    # router must score it against ITS hashing, not instance 0's
    big = _holder_of(p, 2, block_size=2 * BS, free=10)
    handles = [_holder_of(p, 1, free=500), big]
    d = PrefixAffinityRouter().select(handles, [0, 1], prompt=p)
    assert (d.idx, d.matched_blocks) == (1, 2)


# --------------------------------------------------- admission back-off
def test_max_queue_sheds_and_pending_counts():
    handles = [FakeHandle(queue=2), FakeHandle(queue=1)]
    r = PrefixAffinityRouter()
    assert r.select(handles, [0, 1], max_queue=2).idx == 1
    # the accepted-but-unpumped charge fills the last seat -> None = 429
    assert r.select(handles, [0, 1], pending={1: 1}, max_queue=2) is None
    assert VacancyRouter().select(handles, [0, 1], pending={1: 1},
                                  max_queue=2) is None
    assert RoundRobinRouter().select(handles, [0, 1], pending={1: 1},
                                     max_queue=2) is None


def test_round_robin_rotates_over_admissible():
    handles = [FakeHandle(), FakeHandle(queue=9), FakeHandle()]
    rr = RoundRobinRouter()
    picks = [rr.select(handles, [0, 1, 2], max_queue=5).idx
             for _ in range(4)]
    assert picks == [0, 2, 0, 2]               # full instance 1 skipped


# ------------------------------------------------- tier-2 e2e acceptance
@pytest.mark.slow
def test_pod_wide_affinity_through_ingress():
    """ISSUE-8 acceptance: distinct tenants sharing per-tenant prompt
    prefixes, streamed through the REAL HTTP ingress over a 2-instance
    pod — after each tenant's first (cold) request, >= 90% of its
    repeats must route to the chain-holding instance."""
    import json
    import socket

    import jax

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serving.ingress import Ingress
    from repro.serving.orchestrator import Orchestrator

    cfg = get_config("tinyllama-1.1b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), "float32")
    orch = Orchestrator(cfg, params, n_instances=2, max_batch=4,
                        max_len=96, block_size=8, prefix_sharing=True)
    ing = Ingress(orch).start()
    try:
        def complete(prompt):
            s = socket.create_connection(("127.0.0.1", ing.port),
                                         timeout=60)
            body = json.dumps({"prompt": prompt,
                               "max_tokens": 2}).encode()
            s.sendall(b"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
                      b"Content-Length: %d\r\n\r\n" % len(body) + body)
            data = b""
            while chunk := s.recv(65536):
                data += chunk
            s.close()
            return json.loads(data.split(b"\r\n\r\n", 1)[1])

        tenants = [[7 + t] * 40 for t in range(4)]  # 5 full blocks each
        repeats, hits = 0, 0
        homes = {}
        for round_i in range(6):
            for t, prefix in enumerate(tenants):
                reply = complete(prefix + [900 + round_i, 900 + t])
                routing = reply["routing"]
                if round_i == 0:
                    homes[t] = routing["instance"]  # cold: vacancy pick
                    continue
                repeats += 1
                if (routing["reason"] == "prefix"
                        and routing["instance"] == homes[t]):
                    hits += 1
        assert repeats == 20
        assert hits / repeats >= 0.9, (hits, repeats, homes)
    finally:
        ing.close()
        orch.close()
