"""Multi-device behaviour via subprocesses (8 forced host devices).

Covers the paper-critical properties that need a real multi-device mesh:
* replication correctness — a per-layer batch-sharding plan produces
  numerically identical results to the unconstrained model;
* continuity — fragmented plans lower to MORE resharding collectives than
  contiguous ones with the same replica count (§3.1 / Alg. 1's objective);
* migration — re-placement moves the expected bytes and keeps values.
"""
import os
import subprocess
import sys
import textwrap


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str) -> str:
    code = "import os\n" \
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8'\n" \
        + textwrap.dedent(body)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=420)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_replicated_plan_matches_baseline():
    out = run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.core.plan import PlacementPlan
    from repro.core import replication as R

    cfg = get_config('tinyllama-1.1b').reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), 'float32')
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                                cfg.vocab_size)
    base, _, _ = T.forward(params, cfg, tokens, mode='train')

    mesh = R.replication_mesh(8)
    plan = PlacementPlan.initial(cfg.num_layers)
    plan.add_replica(0, 1)          # p_0 = 2
    for d in (1, 2, 3):
        plan.add_replica(1, d)      # p_1 = 4
    hook = R.layer_hook_from_plan(plan, mesh)
    params_r = R.replicate_params_for_plan(params, mesh)
    with mesh:
        out2, _, _ = jax.jit(lambda p, t: T.forward(
            p, cfg, t, mode='train', unroll=True, layer_hook=hook))(
            params_r, tokens)
    np.testing.assert_allclose(np.asarray(base), np.asarray(out2),
                               rtol=2e-4, atol=2e-4)
    print('REPLICATION_OK')
    """)
    assert "REPLICATION_OK" in out


def test_continuity_reduces_collectives():
    out = run_py("""
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.core.plan import PlacementPlan
    from repro.core import replication as R

    cfg = get_config('tinyllama-1.1b').reduced()
    # use more layers to make fragmentation visible
    import dataclasses
    cfg = dataclasses.replace(cfg, num_layers=8)
    params = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0), 'float32'))
    tokens = jax.ShapeDtypeStruct((8, 16), jnp.int32)
    mesh = R.replication_mesh(8)

    def count(plan):
        hook = R.layer_hook_from_plan(plan, mesh)
        with mesh:
            lowered = jax.jit(lambda p, t: T.forward(
                p, cfg, t, mode='train', unroll=True, layer_hook=hook)
                ).lower(params, tokens)
            txt = lowered.compile().as_text()
        return sum(v['count'] if isinstance(v, dict) else v
                   for v in R.count_collectives(txt).values())

    contiguous = PlacementPlan.initial(8)
    fragmented = PlacementPlan.initial(8)
    for i in range(4):
        contiguous.add_replica(i, 1)        # layers 0-3 together
        fragmented.add_replica(2 * i, 1)    # layers 0,2,4,6
    c_cont, c_frag = count(contiguous), count(fragmented)
    print('COLLECTIVES contiguous=%d fragmented=%d' % (c_cont, c_frag))
    assert c_cont < c_frag, (c_cont, c_frag)
    print('CONTINUITY_OK')
    """)
    assert "CONTINUITY_OK" in out


def test_migration_moves_bytes_and_preserves_values():
    out = run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.core import migration as M
    from repro.core.replication import replication_mesh

    cfg = get_config('tinyllama-1.1b').reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), 'float32')
    mesh = replication_mesh(8)
    before = np.asarray(params['layers']['attn']['wq'])
    new_params, cost = M.migrate_by_path(
        params, r'layers/attn', P(), mesh, measure=True)
    expect = M.tree_bytes(params, r'layers/attn')
    assert cost.bytes_moved == expect, (cost.bytes_moved, expect)
    assert cost.est_seconds > 0.2  # fixed overhead floor (Table 2 shape)
    np.testing.assert_array_equal(
        before, np.asarray(new_params['layers']['attn']['wq']))
    print('MIGRATION_OK bytes=%d est=%.3fs measured=%.3fs' % (
        cost.bytes_moved, cost.est_seconds, cost.measured_seconds or -1))
    """)
    assert "MIGRATION_OK" in out


def test_sharded_train_step_runs():
    """A real sharded train step on an 8-device host mesh (data||model)."""
    out = run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.parallel import sharding as SH
    from repro.training import optimizer as OPT, train as TR

    cfg = get_config('qwen2-moe-a2.7b').reduced()
    mesh = jax.make_mesh((4, 2), ('data', 'model'))
    rules = SH.rules_for(cfg, mesh)
    params = T.init_params(cfg, jax.random.PRNGKey(0), 'float32')
    specs = SH.param_specs(cfg, params, rules, mesh)
    params = SH.shard_params(params, specs, mesh)
    opt = OPT.init_opt_state(params)
    step = TR.make_train_step(cfg, OPT.OptimizerConfig(lr=1e-3))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                cfg.vocab_size)
    batch = {'tokens': tokens, 'labels': tokens}
    batch = jax.device_put(batch, NamedSharding(mesh, P('data', None)))
    with mesh:
        with SH.use_rules(rules):
            p2, o2, m = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(m['loss']))
    print('SHARDED_TRAIN_OK loss=%.3f' % float(m['loss']))
    """)
    assert "SHARDED_TRAIN_OK" in out


def test_flash_decode_matches_reference():
    """Distributed flash-decoding (seq-sharded cache) == naive attention."""
    out = run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.parallel.distributed_attention import flash_decode
    from repro.kernels.ref import ref_decode_attention
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    B, H, KV, M, D = 4, 8, 2, 64, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (B,1,H,D), jnp.float32)
    kc = jax.random.normal(jax.random.PRNGKey(1), (B,M,KV,D), jnp.float32)
    vc = jax.random.normal(jax.random.PRNGKey(2), (B,M,KV,D), jnp.float32)
    lens = jnp.array([10, 33, 64, 50], jnp.int32)
    qpos = (lens - 1)[:, None]
    kpos = jnp.broadcast_to(jnp.arange(M, dtype=jnp.int32), (B, M))
    kpos = jnp.where(kpos < lens[:, None], kpos, 2**30)
    with mesh:
        out = jax.jit(lambda *a: flash_decode(
            *a, mesh=mesh, seq_axis="model", batch_axis="data"))(
            q, kc, vc, qpos, kpos)
    ref = ref_decode_attention(q[:,0], kc.transpose(0,2,1,3),
                               vc.transpose(0,2,1,3), lens)
    np.testing.assert_allclose(np.asarray(out[:,0]), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)
    print('FLASH_DECODE_OK')
    """)
    assert "FLASH_DECODE_OK" in out


def test_moe_expert_parallel_matches_dense():
    """shard_map all-to-all MoE == dense oracle (fwd + grad)."""
    out = run_py("""
    import dataclasses, jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models import moe as MOE
    from repro.parallel import sharding as SH
    cfg = dataclasses.replace(get_config('qwen2-moe-a2.7b').reduced(),
                              num_experts=16, num_experts_per_tok=2,
                              num_shared_experts=0)
    mesh = jax.make_mesh((4, 2), ('data', 'model'))
    rules = SH.rules_for(cfg, mesh); rules['mesh'] = mesh
    p = MOE.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model),
                          jnp.float32) * 0.5
    w, idx, _ = MOE.route(p, x, cfg)
    ref = MOE._moe_dense(p, x, w, idx, cfg)
    with mesh:
        got = jax.jit(lambda *a: MOE._moe_expert_parallel(
            *a, cfg, rules, capacity_factor=8.0))(p, x, w, idx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    def loss_ep(p_):
        w_, i_, _ = MOE.route(p_, x, cfg)
        return jnp.sum(MOE._moe_expert_parallel(
            p_, x, w_, i_, cfg, rules, capacity_factor=8.0) ** 2)
    def loss_dense(p_):
        w_, i_, _ = MOE.route(p_, x, cfg)
        return jnp.sum(MOE._moe_dense(p_, x, w_, i_, cfg) ** 2)
    with mesh:
        g1 = jax.jit(jax.grad(loss_ep))(p)
    g2 = jax.grad(loss_dense)(p)
    np.testing.assert_allclose(np.asarray(g1['w_down']),
                               np.asarray(g2['w_down']),
                               rtol=5e-3, atol=5e-3)
    print('MOE_A2A_OK')
    """)
    assert "MOE_A2A_OK" in out


def test_mla_flash_decode_matches_reference():
    """Absorbed-MLA distributed flash-decoding == single-device decode."""
    out = run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.parallel import sharding as SH
    cfg = get_config('minicpm3-4b').reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), 'float32')
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0,
                                cfg.vocab_size)
    cache = T.init_cache(cfg, 4, 64, 'float32')
    _, cache, _ = T.forward(params, cfg, tokens, mode='prefill', cache=cache)
    pos = jnp.full((4, 1), 12, jnp.int32)
    ref, _, _ = T.forward(params, cfg, tokens[:, :1], positions=pos,
                          mode='decode', cache=cache)
    mesh = jax.make_mesh((2, 4), ('data', 'model'))
    rules = SH.rules_for(cfg, mesh)
    rules.update(mesh=mesh, flash_decode=True, cache_seq='model')
    with mesh, SH.use_rules(rules):
        got, _, _ = jax.jit(lambda p, t, po, c: T.forward(
            p, cfg, t, positions=po, mode='decode', cache=c))(
            params, tokens[:, :1], pos, cache)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    print('MLA_FLASH_OK')
    """)
    assert "MLA_FLASH_OK" in out
