"""Pod inventory launcher (launch/pod.py), tier-1 slice: inventory
parsing (TOML and JSON), endpoint expansion, schema validation, and the
``--show`` CLI — everything that needs no engine-server spawn. The
spawned/attached TCP pod itself is exercised by the tier-2 suite
(tests/test_distributed_plane.py) and the distributed benchmark."""
import json

import pytest

from repro.launch.pod import Node, load_inventory, main, parse_inventory

TOML_DOC = """\
# two-machine pod: this host spawns, the second is attached
[[node]]
host = "127.0.0.1"
port = 7101
capacity = 2

[[node]]
host = "10.0.0.7"
port = 7201
capacity = 1
spawn = false
"""


def test_load_toml_inventory(tmp_path):
    path = tmp_path / "pod.toml"
    path.write_text(TOML_DOC)
    nodes = load_inventory(str(path))
    assert nodes == [Node(host="127.0.0.1", port=7101, capacity=2,
                          spawn=True),
                     Node(host="10.0.0.7", port=7201, capacity=1,
                          spawn=False)]
    # capacity k -> k consecutive ports on the node
    assert nodes[0].endpoints() == ["tcp://127.0.0.1:7101",
                                    "tcp://127.0.0.1:7102"]
    assert nodes[1].endpoints() == ["tcp://10.0.0.7:7201"]


def test_load_json_inventory(tmp_path):
    path = tmp_path / "pod.json"
    path.write_text(json.dumps({"node": [
        {"host": "127.0.0.1", "port": 7301},
    ]}))
    (node,) = load_inventory(str(path))
    assert node == Node(host="127.0.0.1", port=7301, capacity=1,
                        spawn=True)


@pytest.mark.parametrize("doc,msg", [
    ({}, "non-empty"),
    ({"node": []}, "non-empty"),
    ({"node": ["tcp://x:1"]}, "not a table"),
    ({"node": [{"host": "h"}]}, "port"),
    ({"node": [{"host": "h", "port": 1, "cap": 2}]}, "unknown keys"),
    ({"node": [{"host": "h", "port": 1, "capacity": 0}]}, "capacity"),
    ({"node": [{"host": "h", "port": 99999}]}, "out of range"),
    ({"node": [{"host": "h", "port": 7101, "capacity": 2},
               {"host": "h", "port": 7102}]}, "cannot share"),
])
def test_inventory_schema_rejections(doc, msg):
    with pytest.raises(ValueError, match=msg):
        parse_inventory(doc)


def test_show_cli_prints_expanded_endpoints(tmp_path, capsys):
    path = tmp_path / "pod.toml"
    path.write_text(TOML_DOC)
    assert main(["--show", str(path)]) == 0
    out = capsys.readouterr().out
    assert "tcp://127.0.0.1:7101  (spawn)" in out
    assert "tcp://127.0.0.1:7102  (spawn)" in out
    assert "tcp://10.0.0.7:7201  (attach)" in out


# ---------------------------------------------------- bring-up deadline
class _SlowProxy:
    """EngineProxy stand-in whose bring-up costs 0.15s of wall time —
    enough to walk a pod deadline past its budget without any real
    engine server."""
    instances = []

    def __init__(self, cfg, params, *, endpoint, spawn, adopt_process,
                 start_timeout, peer_label, **kw):
        import time
        time.sleep(0.15)
        self.endpoint = endpoint
        self.peer_label = peer_label
        self.process = adopt_process
        self.start_timeout = start_timeout
        self.closed = False
        _SlowProxy.instances.append(self)

    def close(self):
        self.closed = True


class _FakeProc:
    """Records the lifecycle the reaper must drive (every instance ever
    constructed lands in ``_all`` so the reap test can find unadopted
    children)."""
    _all = []

    def __init__(self, target=None, args=(), daemon=True):
        self.started = self.killed = self.joined = False
        _FakeProc._all.append(self)

    def start(self):
        self.started = True

    def is_alive(self):
        return self.started and not self.killed

    def kill(self):
        self.killed = True

    def join(self, timeout=None):
        self.joined = True


class _FakeCtx:
    Process = _FakeProc


@pytest.fixture(autouse=True)
def _reset_fakes():
    _SlowProxy.instances = []
    _FakeProc._all = []
    yield
    _SlowProxy.instances = []
    _FakeProc._all = []


def test_pod_timeout_bounds_total_bring_up(monkeypatch):
    """Satellite: ``pod_timeout`` is a TOTAL wall deadline — one slow
    endpoint after another must fail the pod once the budget is gone,
    and every handle brought up before the failure is closed."""
    from repro.launch.pod import launch_pod
    from repro.serving import transport as TR
    monkeypatch.setattr("repro.serving.remote_engine.EngineProxy",
                        _SlowProxy)
    nodes = [Node(host="127.0.0.1", port=7101, capacity=4, spawn=False)]
    with pytest.raises(TR.TransportError, match="deadline"):
        launch_pod(None, None, nodes, pod_timeout=0.25)
    assert 0 < len(_SlowProxy.instances) < 4
    assert all(h.closed for h in _SlowProxy.instances)


def test_pod_timeout_budget_shrinks_but_generous_deadline_succeeds(
        monkeypatch):
    from repro.launch.pod import launch_pod
    monkeypatch.setattr("repro.serving.remote_engine.EngineProxy",
                        _SlowProxy)
    nodes = [Node(host="127.0.0.1", port=7101, capacity=4, spawn=False)]
    handles = launch_pod(None, None, nodes, pod_timeout=30.0)
    assert [h.peer_label for h in handles] == ["w0", "w1", "w2", "w3"]
    # each endpoint's budget is what REMAINS of the pod deadline, so it
    # strictly shrinks along the bring-up order
    budgets = [h.start_timeout for h in handles]
    assert all(b > a for a, b in zip(budgets[1:], budgets))
    assert not any(h.closed for h in handles)


def test_pod_deadline_reaps_spawned_but_unadopted_children(monkeypatch):
    """Satellite: when the pod deadline fires mid-launch, server
    processes that were spawned in phase one but never adopted by a
    proxy must be killed and joined — no orphans."""
    monkeypatch.setattr("repro.serving.remote_engine.EngineProxy",
                        _SlowProxy)
    monkeypatch.setattr("multiprocessing.get_context",
                        lambda method: _FakeCtx)
    from repro.launch.pod import launch_pod
    from repro.serving import transport as TR
    nodes = [Node(host="127.0.0.1", port=7101, capacity=4, spawn=True)]
    with pytest.raises(TR.TransportError, match="deadline"):
        launch_pod(None, None, nodes, pod_timeout=0.25)
    # phase one spawned one child per endpoint before any dialing
    assert len(_FakeProc._all) == 4
    assert all(p.started for p in _FakeProc._all)
    # adopted children belong to their (now-closed) handles and are
    # left alone; the unadopted rest must be killed AND joined
    adopted = {id(h.process) for h in _SlowProxy.instances}
    assert adopted and len(adopted) < 4
    reaped = [p for p in _FakeProc._all if id(p) not in adopted]
    assert reaped, "expected at least one unadopted child"
    assert all(p.killed and p.joined for p in reaped)
    assert not any(p.killed for p in _FakeProc._all
                   if id(p) in adopted)


# =================================================== pod elasticity (§11)
# Controller decision logic on synthetic snapshots (cheap), then the
# orchestrator's grow/shrink MECHANISM on real engines: a runtime-spawned
# worker takes routed traffic, a drained worker hands its streams off
# token-identically, and the flap guard keeps a booting worker alive.
import time as _time

from repro.core.cluster import Cluster
from repro.core.controller import (Controller, ControllerConfig,
                                   PodElasticityConfig)
from repro.core.monitor import MetricsSnapshot, Monitor
from repro.core.plan import PlacementPlan


def _pod_ctrl(pcfg=None):
    mon = Monitor()
    return Controller(ControllerConfig(), Cluster.homogeneous(2),
                      PlacementPlan.initial(4), mon,
                      pod_cfg=pcfg or PodElasticityConfig()), mon


def _snap(vac, queue=0, t=0.0):
    return MetricsSnapshot(t=t, queue_len=queue,
                           block_vacancy=[vac, vac],
                           device_util=[1 - vac, 1 - vac])


def test_pod_tick_grow_needs_patience_then_cooldown():
    ctrl, mon = _pod_ctrl(PodElasticityConfig(patience=2,
                                              cooldown_ticks=3))
    mon.record(_snap(vac=0.05))               # pools nearly full
    assert ctrl.pod_tick(pod_size=2) is None  # vote 1 of 2
    assert ctrl.pod_tick(pod_size=2) == "grow"
    assert any(a.startswith("grow-pod") for a in ctrl.log)
    # the action re-armed the pod cooldown: pressure is ignored for 3
    for _ in range(3):
        assert ctrl.pod_tick(pod_size=2) is None
    assert ctrl.pod_tick(pod_size=2) is None  # cooldown over: vote 1
    assert ctrl.pod_tick(pod_size=2) == "grow"


def test_pod_tick_backlog_pressure_and_vote_reset():
    ctrl, mon = _pod_ctrl(PodElasticityConfig(patience=2))
    mon.record(_snap(vac=0.5, queue=20))      # backlog 10/instance > 4
    assert ctrl.pod_tick(pod_size=2) is None
    mon.record(_snap(vac=0.5, queue=0))       # neutral tick RESETS votes
    assert ctrl.pod_tick(pod_size=2) is None
    mon.record(_snap(vac=0.5, queue=20))
    assert ctrl.pod_tick(pod_size=2) is None  # back to vote 1
    assert ctrl.pod_tick(pod_size=2) == "grow"


def test_pod_tick_respects_size_bounds():
    ctrl, mon = _pod_ctrl(PodElasticityConfig(patience=1,
                                              max_instances=2,
                                              min_instances=2))
    mon.record(_snap(vac=0.02))
    assert ctrl.pod_tick(pod_size=2) is None  # at the ceiling: no grow
    mon.record(_snap(vac=0.99, queue=0))
    assert ctrl.pod_tick(pod_size=2) is None  # at the floor: no shrink


def test_pod_tick_shrink_gated_by_drain_cost():
    pcfg = PodElasticityConfig(patience=2, max_drain_s=1.0)
    ctrl, mon = _pod_ctrl(pcfg)
    mon.record(_snap(vac=0.95, queue=0))      # idle pod
    assert ctrl.pod_tick(pod_size=2) is None
    # Table-2 cost gate: too expensive to drain -> skipped, logged
    assert ctrl.pod_tick(pod_size=2, est_drain_s=9.0) is None
    assert any("shrink-pod-skipped" in a for a in ctrl.log)
    assert ctrl.pod_tick(pod_size=2) is None  # votes were consumed
    assert ctrl.pod_tick(pod_size=2, est_drain_s=0.1) == "shrink"
    assert any(a.startswith("shrink-pod[") for a in ctrl.log)


# ------------------------------------------------ live grow/shrink (slowish)
import jax
import numpy as np
import pytest as _pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving.engine import Engine
from repro.serving.request import RequestSpec
from repro.serving.orchestrator import Orchestrator
from repro.launch.pod import make_worker_factory

ENG_KW = dict(max_batch=2, max_len=64, block_size=8)


@_pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama-1.1b").reduced()
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0), "float32")


def _elastic_orch(cfg, params, n=1, **pod_kw):
    pod_kw.setdefault("max_instances", 4)
    pod_kw.setdefault("flap_guard_s", 0.3)
    return Orchestrator(cfg, params, n_instances=n,
                        worker_factory=make_worker_factory(cfg, params,
                                                           **ENG_KW),
                        pod_cfg=PodElasticityConfig(**pod_kw), **ENG_KW)


def _reqs(n, max_new=6, plen=12):
    rng = np.random.default_rng(3)
    return [RequestSpec(rid=100 + i,
                        prompt=rng.integers(2, 1000, size=plen)
                        .astype(np.int32),
                        max_tokens=max_new) for i in range(n)]


def _solo_reference(cfg, params, requests):
    out = {}
    for r in requests:
        e = Engine(cfg, params, max_batch=1, cache_kind="paged",
                   max_len=64, block_size=8)
        e.submit(r)
        out[r.rid] = e.run_until_done()[0].generated
    return out


def test_runtime_grown_worker_takes_routed_traffic(tiny):
    cfg, params = tiny
    orch = _elastic_orch(cfg, params, n=1)
    try:
        warm, req = _reqs(2, max_new=16)
        orch.submit(warm)
        orch.step()                           # warm holds blocks on 0
        idx = orch.grow_pod()
        assert idx == 1 and orch.pod_size() == 2
        assert orch.pod_log and orch.pod_log[-1]["event"] == "grow"
        # the fleet snapshot immediately covers the new worker
        snap = orch.snapshot()
        assert len(snap.block_vacancy) == 2
        assert snap.pod_size == 2
        # vacancy routing prefers the empty newcomer over the busy
        # original (warm's stream is holding pool blocks on instance 0)
        d = orch.route(prompt=req.prompt)
        assert d.idx == 1 and d.reason == "vacancy"
        orch.submit_to(d.idx, req)
        orch.run_until_done()
        assert {r.rid for r in orch.finished} == {warm.rid, req.rid}
        assert orch.instances[1].telemetry.total_finished == 1
        assert orch.dropped == 0
    finally:
        orch.close()


def test_shrink_hands_streams_off_token_identically(tiny):
    """ISSUE-8 acceptance: draining a worker mid-decode through
    shrink_pod moves its streams to the survivor with ZERO drops and
    token-identical output vs the solo-engine oracle; the retired slot
    goes dark (None telemetry, never stepped, never reused)."""
    cfg, params = tiny
    orch = _elastic_orch(cfg, params, n=2, min_instances=1)
    try:
        requests = _reqs(4, max_new=8)
        for r in requests:
            orch.submit(r)
        for _ in range(3):                    # get streams mid-flight
            orch.step()
        assert any(orch.instances[1].active_rids())
        assert orch.shrink_pod(1) == 1
        assert 1 in orch._retired and orch.pod_size() == 1
        orch.run_until_done()
        assert len(orch.finished) == len(requests) and orch.dropped == 0
        ref = _solo_reference(cfg, params, requests)
        for r in orch.finished:
            assert list(r.generated) == list(ref[r.rid]), r.rid
        # retired slot: dark in telemetry, skipped by routing/stepping
        snap = orch.snapshot()
        assert snap.block_vacancy[1] is None
        assert snap.device_util[1] is None
        assert snap.pod_size == 1
        assert orch.route(prompt=requests[0].prompt).idx == 0
        orch.step()                           # must not touch the corpse
        # ...and never reused: the next grow takes a FRESH index
        assert orch.grow_pod() == 2
        assert 1 in orch._retired
    finally:
        orch.close()


def test_flap_guard_protects_booting_worker(tiny):
    cfg, params = tiny
    orch = _elastic_orch(cfg, params, n=1, flap_guard_s=0.4)
    try:
        idx = orch.grow_pod()
        assert idx == 1
        # inside the guard window the newcomer is not a shrink target:
        # an explicit request for it is refused, and the auto-picked
        # victim can only be the OLD worker
        assert orch.shrink_pod(idx) is None
        assert orch._shrink_target()[0] == 0
        assert orch.pod_size() == 2
        _time.sleep(0.45)
        assert orch.shrink_pod(idx) == 1
        assert orch.pod_size() == 1
    finally:
        orch.close()


def test_worker_factory_builds_local_paged_instances(tiny):
    cfg, params = tiny
    factory = make_worker_factory(cfg, params, **ENG_KW)
    h = factory(0)
    try:
        assert h.block_size == 8
        assert h.free_blocks() > 0 and h.alive()
        assert h.prefix_keys() == set()
    finally:
        h.close()
