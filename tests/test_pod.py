"""Pod inventory launcher (launch/pod.py), tier-1 slice: inventory
parsing (TOML and JSON), endpoint expansion, schema validation, and the
``--show`` CLI — everything that needs no engine-server spawn. The
spawned/attached TCP pod itself is exercised by the tier-2 suite
(tests/test_distributed_plane.py) and the distributed benchmark."""
import json

import pytest

from repro.launch.pod import Node, load_inventory, main, parse_inventory

TOML_DOC = """\
# two-machine pod: this host spawns, the second is attached
[[node]]
host = "127.0.0.1"
port = 7101
capacity = 2

[[node]]
host = "10.0.0.7"
port = 7201
capacity = 1
spawn = false
"""


def test_load_toml_inventory(tmp_path):
    path = tmp_path / "pod.toml"
    path.write_text(TOML_DOC)
    nodes = load_inventory(str(path))
    assert nodes == [Node(host="127.0.0.1", port=7101, capacity=2,
                          spawn=True),
                     Node(host="10.0.0.7", port=7201, capacity=1,
                          spawn=False)]
    # capacity k -> k consecutive ports on the node
    assert nodes[0].endpoints() == ["tcp://127.0.0.1:7101",
                                    "tcp://127.0.0.1:7102"]
    assert nodes[1].endpoints() == ["tcp://10.0.0.7:7201"]


def test_load_json_inventory(tmp_path):
    path = tmp_path / "pod.json"
    path.write_text(json.dumps({"node": [
        {"host": "127.0.0.1", "port": 7301},
    ]}))
    (node,) = load_inventory(str(path))
    assert node == Node(host="127.0.0.1", port=7301, capacity=1,
                        spawn=True)


@pytest.mark.parametrize("doc,msg", [
    ({}, "non-empty"),
    ({"node": []}, "non-empty"),
    ({"node": ["tcp://x:1"]}, "not a table"),
    ({"node": [{"host": "h"}]}, "port"),
    ({"node": [{"host": "h", "port": 1, "cap": 2}]}, "unknown keys"),
    ({"node": [{"host": "h", "port": 1, "capacity": 0}]}, "capacity"),
    ({"node": [{"host": "h", "port": 99999}]}, "out of range"),
    ({"node": [{"host": "h", "port": 7101, "capacity": 2},
               {"host": "h", "port": 7102}]}, "cannot share"),
])
def test_inventory_schema_rejections(doc, msg):
    with pytest.raises(ValueError, match=msg):
        parse_inventory(doc)


def test_show_cli_prints_expanded_endpoints(tmp_path, capsys):
    path = tmp_path / "pod.toml"
    path.write_text(TOML_DOC)
    assert main(["--show", str(path)]) == 0
    out = capsys.readouterr().out
    assert "tcp://127.0.0.1:7101  (spawn)" in out
    assert "tcp://127.0.0.1:7102  (spawn)" in out
    assert "tcp://10.0.0.7:7201  (attach)" in out
