"""Pod inventory launcher (launch/pod.py), tier-1 slice: inventory
parsing (TOML and JSON), endpoint expansion, schema validation, and the
``--show`` CLI — everything that needs no engine-server spawn. The
spawned/attached TCP pod itself is exercised by the tier-2 suite
(tests/test_distributed_plane.py) and the distributed benchmark."""
import json

import pytest

from repro.launch.pod import Node, load_inventory, main, parse_inventory

TOML_DOC = """\
# two-machine pod: this host spawns, the second is attached
[[node]]
host = "127.0.0.1"
port = 7101
capacity = 2

[[node]]
host = "10.0.0.7"
port = 7201
capacity = 1
spawn = false
"""


def test_load_toml_inventory(tmp_path):
    path = tmp_path / "pod.toml"
    path.write_text(TOML_DOC)
    nodes = load_inventory(str(path))
    assert nodes == [Node(host="127.0.0.1", port=7101, capacity=2,
                          spawn=True),
                     Node(host="10.0.0.7", port=7201, capacity=1,
                          spawn=False)]
    # capacity k -> k consecutive ports on the node
    assert nodes[0].endpoints() == ["tcp://127.0.0.1:7101",
                                    "tcp://127.0.0.1:7102"]
    assert nodes[1].endpoints() == ["tcp://10.0.0.7:7201"]


def test_load_json_inventory(tmp_path):
    path = tmp_path / "pod.json"
    path.write_text(json.dumps({"node": [
        {"host": "127.0.0.1", "port": 7301},
    ]}))
    (node,) = load_inventory(str(path))
    assert node == Node(host="127.0.0.1", port=7301, capacity=1,
                        spawn=True)


@pytest.mark.parametrize("doc,msg", [
    ({}, "non-empty"),
    ({"node": []}, "non-empty"),
    ({"node": ["tcp://x:1"]}, "not a table"),
    ({"node": [{"host": "h"}]}, "port"),
    ({"node": [{"host": "h", "port": 1, "cap": 2}]}, "unknown keys"),
    ({"node": [{"host": "h", "port": 1, "capacity": 0}]}, "capacity"),
    ({"node": [{"host": "h", "port": 99999}]}, "out of range"),
    ({"node": [{"host": "h", "port": 7101, "capacity": 2},
               {"host": "h", "port": 7102}]}, "cannot share"),
])
def test_inventory_schema_rejections(doc, msg):
    with pytest.raises(ValueError, match=msg):
        parse_inventory(doc)


def test_show_cli_prints_expanded_endpoints(tmp_path, capsys):
    path = tmp_path / "pod.toml"
    path.write_text(TOML_DOC)
    assert main(["--show", str(path)]) == 0
    out = capsys.readouterr().out
    assert "tcp://127.0.0.1:7101  (spawn)" in out
    assert "tcp://127.0.0.1:7102  (spawn)" in out
    assert "tcp://10.0.0.7:7201  (attach)" in out


# ---------------------------------------------------- bring-up deadline
class _SlowProxy:
    """EngineProxy stand-in whose bring-up costs 0.15s of wall time —
    enough to walk a pod deadline past its budget without any real
    engine server."""
    instances = []

    def __init__(self, cfg, params, *, endpoint, spawn, adopt_process,
                 start_timeout, peer_label, **kw):
        import time
        time.sleep(0.15)
        self.endpoint = endpoint
        self.peer_label = peer_label
        self.process = adopt_process
        self.start_timeout = start_timeout
        self.closed = False
        _SlowProxy.instances.append(self)

    def close(self):
        self.closed = True


class _FakeProc:
    """Records the lifecycle the reaper must drive (every instance ever
    constructed lands in ``_all`` so the reap test can find unadopted
    children)."""
    _all = []

    def __init__(self, target=None, args=(), daemon=True):
        self.started = self.killed = self.joined = False
        _FakeProc._all.append(self)

    def start(self):
        self.started = True

    def is_alive(self):
        return self.started and not self.killed

    def kill(self):
        self.killed = True

    def join(self, timeout=None):
        self.joined = True


class _FakeCtx:
    Process = _FakeProc


@pytest.fixture(autouse=True)
def _reset_fakes():
    _SlowProxy.instances = []
    _FakeProc._all = []
    yield
    _SlowProxy.instances = []
    _FakeProc._all = []


def test_pod_timeout_bounds_total_bring_up(monkeypatch):
    """Satellite: ``pod_timeout`` is a TOTAL wall deadline — one slow
    endpoint after another must fail the pod once the budget is gone,
    and every handle brought up before the failure is closed."""
    from repro.launch.pod import launch_pod
    from repro.serving import transport as TR
    monkeypatch.setattr("repro.serving.remote_engine.EngineProxy",
                        _SlowProxy)
    nodes = [Node(host="127.0.0.1", port=7101, capacity=4, spawn=False)]
    with pytest.raises(TR.TransportError, match="deadline"):
        launch_pod(None, None, nodes, pod_timeout=0.25)
    assert 0 < len(_SlowProxy.instances) < 4
    assert all(h.closed for h in _SlowProxy.instances)


def test_pod_timeout_budget_shrinks_but_generous_deadline_succeeds(
        monkeypatch):
    from repro.launch.pod import launch_pod
    monkeypatch.setattr("repro.serving.remote_engine.EngineProxy",
                        _SlowProxy)
    nodes = [Node(host="127.0.0.1", port=7101, capacity=4, spawn=False)]
    handles = launch_pod(None, None, nodes, pod_timeout=30.0)
    assert [h.peer_label for h in handles] == ["w0", "w1", "w2", "w3"]
    # each endpoint's budget is what REMAINS of the pod deadline, so it
    # strictly shrinks along the bring-up order
    budgets = [h.start_timeout for h in handles]
    assert all(b > a for a, b in zip(budgets[1:], budgets))
    assert not any(h.closed for h in handles)


def test_pod_deadline_reaps_spawned_but_unadopted_children(monkeypatch):
    """Satellite: when the pod deadline fires mid-launch, server
    processes that were spawned in phase one but never adopted by a
    proxy must be killed and joined — no orphans."""
    monkeypatch.setattr("repro.serving.remote_engine.EngineProxy",
                        _SlowProxy)
    monkeypatch.setattr("multiprocessing.get_context",
                        lambda method: _FakeCtx)
    from repro.launch.pod import launch_pod
    from repro.serving import transport as TR
    nodes = [Node(host="127.0.0.1", port=7101, capacity=4, spawn=True)]
    with pytest.raises(TR.TransportError, match="deadline"):
        launch_pod(None, None, nodes, pod_timeout=0.25)
    # phase one spawned one child per endpoint before any dialing
    assert len(_FakeProc._all) == 4
    assert all(p.started for p in _FakeProc._all)
    # adopted children belong to their (now-closed) handles and are
    # left alone; the unadopted rest must be killed AND joined
    adopted = {id(h.process) for h in _SlowProxy.instances}
    assert adopted and len(adopted) < 4
    reaped = [p for p in _FakeProc._all if id(p) not in adopted]
    assert reaped, "expected at least one unadopted child"
    assert all(p.killed and p.joined for p in reaped)
    assert not any(p.killed for p in _FakeProc._all
                   if id(p) in adopted)
