"""Smoke tests for the documented entry points (README quickstarts).

Runs ``examples/quickstart.py`` and ``examples/shared_prefix.py`` as real
subprocesses under a tiny config, so the commands the README advertises
can't silently rot. Assertions check the banner lines each script prints
on success, not just the exit code.
"""
import os
import re
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_example(args, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run([sys.executable] + args, cwd=REPO, env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, \
        f"{args} failed:\nstdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    return proc.stdout


def test_quickstart_runs():
    out = _run_example(["examples/quickstart.py"])
    assert "greedy tokens:" in out
    assert "paged engine rid=" in out          # the primary decode path ran
    assert "scale-up: replicated" in out       # the CoCoServe plan step ran


def test_shared_prefix_example_runs():
    out = _run_example(["examples/shared_prefix.py", "--streams", "4",
                        "--sys-len", "16", "--max-new", "4"])
    assert "[sharing OFF]" in out and "[sharing ON ]" in out
    assert "token-identical: True" in out
    # the demo's headline: sharing held fewer peak blocks
    m = re.search(r"\((\d+) saved", out)
    assert m and int(m.group(1)) > 0, out
